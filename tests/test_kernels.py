"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.super_gmm.ops import _pick_blocks, make_super_kernel_gmm, \
    super_moe_ffn
from repro.kernels.super_gmm.ref import super_gmm_ref, super_moe_ffn_ref
from repro.kernels.super_gmm.super_gmm import super_gmm
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.dispatch_combine.ops import (kernel_moe_combine,
                                                kernel_moe_dispatch)
from repro.models.common import ModelConfig
from repro.models.moe import moe_combine, moe_dispatch, router_topk


# ---------------------------------------------------------------- super gmm

@pytest.mark.parametrize("L,E,C,K,N", [(3, 4, 16, 32, 64), (2, 2, 128, 128, 256),
                                       (5, 8, 8, 16, 8), (1, 1, 32, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_super_gmm_sweep(L, E, C, K, N, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    w = jax.random.normal(ks[0], (L, E, K, N), jnp.float32).astype(dtype)
    x = jax.random.normal(ks[1], (E, C, K), jnp.float32).astype(dtype)
    bc, bn, bk = _pick_blocks(C, N, K)
    for lid in (0, L - 1):
        out = super_gmm(jnp.array([lid], jnp.int32), w, x, block_c=bc,
                        block_n=bn, block_k=bk)
        ref = super_gmm_ref(jnp.array(lid), w, x)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol,
                                   atol=tol)


@pytest.mark.parametrize("L,E,C,K,N", [(2, 2, 192, 160, 192),
                                       (1, 3, 24, 48, 96)])
def test_super_gmm_non_power_of_two_dims(L, E, C, K, N):
    """dims that a bare min(block, dim) clamp would misindex (192 vs 128):
    the divisor rounding must pick a dividing block and stay correct."""
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    w = jax.random.normal(ks[0], (L, E, K, N))
    x = jax.random.normal(ks[1], (E, C, K))
    out = super_gmm(jnp.array([L - 1], jnp.int32), w, x,
                    block_c=128, block_n=128, block_k=128)
    ref = super_gmm_ref(jnp.array(L - 1), w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_floor_to_divisor():
    from repro.kernels.blocking import floor_to_divisor
    assert floor_to_divisor(192, 128) == 96
    assert floor_to_divisor(256, 128) == 128
    assert floor_to_divisor(100, 128) == 100  # block >= dim -> whole dim
    assert floor_to_divisor(97, 64) == 1      # prime dim still launches
    with pytest.raises(ValueError, match="must be positive"):
        floor_to_divisor(0, 128)
    with pytest.raises(ValueError, match="must be positive"):
        floor_to_divisor(128, -1)


def test_super_gmm_layer_is_runtime_data():
    """One jit trace serves every layer id (the layer-oblivious property)."""
    L, E, C, K, N = 4, 2, 16, 16, 16
    w = jax.random.normal(jax.random.PRNGKey(0), (L, E, K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (E, C, K))
    outs = [super_gmm(jnp.array([l], jnp.int32), w, x, block_c=8, block_n=8,
                      block_k=8) for l in range(L)]
    refs = [super_gmm_ref(jnp.array(l), w, x) for l in range(L)]
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=1e-5,
                                   atol=1e-5)
    # distinct layers give distinct results (weights actually indexed)
    assert np.abs(np.asarray(outs[0] - outs[1])).max() > 1e-3


def test_super_moe_ffn_matches_ref():
    cfg = ModelConfig(name="k", family="moe", num_layers=3, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, num_experts=4, top_k=2, moe_d_ff=48,
                      dtype=jnp.float32)
    L, E, d, f = 3, 4, 32, 48
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    experts = {"w_gate": jax.random.normal(ks[0], (L, E, d, f)),
               "w_up": jax.random.normal(ks[1], (L, E, d, f)),
               "w_down": jax.random.normal(ks[2], (L, E, f, d))}
    xb = jax.random.normal(ks[3], (E, 16, d))
    from repro.models.common import act_fn
    for lid in range(L):
        out = super_moe_ffn(jnp.array([lid], jnp.int32), experts, xb, cfg)
        ref = super_moe_ffn_ref(jnp.array(lid), experts, xb, act_fn(cfg.act))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                                   atol=2e-4)


def test_super_moe_ffn_ref_kernel_option():
    """kernel="ref" must match the Pallas grid bit-for-bit in fp32."""
    cfg = ModelConfig(name="k", family="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, num_experts=4, top_k=2, moe_d_ff=48,
                      dtype=jnp.float32)
    L, E, d, f = 2, 4, 32, 48
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    experts = {"w_gate": jax.random.normal(ks[0], (L, E, d, f)),
               "w_up": jax.random.normal(ks[1], (L, E, d, f)),
               "w_down": jax.random.normal(ks[2], (L, E, f, d))}
    xb = jax.random.normal(ks[3], (E, 16, d))
    lid = jnp.array([1], jnp.int32)
    out_p = super_moe_ffn(lid, experts, xb, cfg, kernel="pallas")
    out_r = super_moe_ffn(lid, experts, xb, cfg, kernel="ref")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- capacity packing

def test_pack_unpack_capacity_roundtrip():
    from repro.kernels.super_gmm.ops import (pack_capacity, round_capacity,
                                             unpack_capacity)
    rng = np.random.RandomState(0)
    for n, n_experts in [(1, 1), (7, 3), (64, 4), (129, 8)]:
        tokens = rng.randn(n, 16).astype(np.float32)
        eids = rng.randint(0, n_experts, n)
        xb, order, slots, C = pack_capacity(tokens, eids, n_experts)
        counts = np.bincount(eids, minlength=n_experts)
        assert C == round_capacity(counts.max())
        assert xb.shape == (n_experts, C, 16)
        # every row landed in its own expert's buffer, in arrival order
        for e in range(n_experts):
            rows = tokens[eids == e]
            np.testing.assert_array_equal(xb[e, :len(rows)], rows)
            assert not xb[e, len(rows):].any()  # padding stays zero
        # unpack inverts pack exactly (identity FFN)
        out = unpack_capacity(xb, order, slots, n)
        np.testing.assert_array_equal(out, tokens)


def test_pack_capacity_rejects_dropping_capacity():
    from repro.kernels.super_gmm.ops import pack_capacity
    tokens = np.ones((10, 4), np.float32)
    eids = np.zeros(10, np.int64)
    with pytest.raises(AssertionError):
        pack_capacity(tokens, eids, 1, capacity=8)  # 10 rows won't fit


def test_pack_capacity_multi_roundtrip_and_bit_equality():
    """ISSUE 10: packing SEVERAL regions into one shared capacity buffer and
    running one super-kernel launch must be BIT-equal to running each region
    through its own pack -> launch -> unpack.  Every capacity row is an
    independent dot chain, so merging changes WHERE a row sits, never the
    reduction order — checked with a real (ref-kernel) expert FFN, not just
    the identity."""
    from repro.kernels.super_gmm.ops import (pack_capacity, pack_capacity_multi,
                                             unpack_capacity,
                                             unpack_capacity_multi)
    rng = np.random.RandomState(7)
    n_experts, d, f = 4, 16, 32
    L = 2
    experts = {
        "w_gate": jnp.asarray(rng.randn(L, n_experts, d, f), jnp.float32),
        "w_up": jnp.asarray(rng.randn(L, n_experts, d, f), jnp.float32),
        "w_down": jnp.asarray(rng.randn(L, n_experts, f, d), jnp.float32),
    }
    cfg = ModelConfig(name="k", family="moe", vocab_size=8, d_model=d,
                      d_ff=f, num_layers=L, num_heads=2, num_kv_heads=2,
                      head_dim=8, num_experts=n_experts, top_k=2, moe_d_ff=f,
                      dtype=jnp.float32)
    lid = jnp.asarray([1], jnp.int32)

    def ffn(xb):
        return np.asarray(super_moe_ffn(lid, experts, xb.astype(np.float32),
                                        cfg, kernel="ref"))

    sizes = [5, 1, 12, 3]
    token_list = [rng.randn(n, d).astype(np.float32) for n in sizes]
    eid_list = [rng.randint(0, n_experts, n) for n in sizes]

    # merged: one pack, ONE launch, split outputs by provenance bounds
    xb, order, slots, C, bounds = pack_capacity_multi(
        token_list, eid_list, n_experts)
    assert list(bounds) == list(np.cumsum(sizes))
    outs_multi = unpack_capacity_multi(ffn(xb), order, slots, bounds)

    # per-region reference: own pack/launch/unpack each — with the MERGED
    # bucket C so the jitted shape matches, and separately with each
    # region's OWN bucket (the per-region serving path)
    for r, (tokens, eids) in enumerate(zip(token_list, eid_list)):
        for cap in (C, None):
            xb1, o1, s1, _ = pack_capacity(tokens, eids, n_experts,
                                           capacity=cap)
            ref = unpack_capacity(ffn(xb1), o1, s1, len(tokens))
            np.testing.assert_array_equal(outs_multi[r], ref)

    # single-region degenerate case: multi == plain pack
    xb1, o1, s1, C1, b1 = pack_capacity_multi(token_list[:1], eid_list[:1],
                                              n_experts)
    xb2, o2, s2, C2 = pack_capacity(token_list[0], eid_list[0], n_experts)
    np.testing.assert_array_equal(xb1, xb2)
    assert C1 == C2 and list(b1) == [sizes[0]]

    # empty region list is a caller bug, not a silent no-op
    with pytest.raises(AssertionError):
        pack_capacity_multi([], [], n_experts)


def test_round_capacity_buckets():
    from repro.kernels.super_gmm.ops import round_capacity
    assert round_capacity(0) == 8
    assert round_capacity(1) == 8
    assert round_capacity(8) == 8
    assert round_capacity(9) == 16
    assert round_capacity(100) == 128
    # bucketing -> O(log N) distinct shapes for the jit cache
    assert len({round_capacity(n) for n in range(1, 1000)}) <= 8


def test_lm_forward_with_super_kernel_matches_einsum():
    from repro.configs import get_config
    from repro.models.lm import init_lm_params, lm_forward
    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=3, num_experts=4, top_k=2, capacity_factor=8.0)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    gmm = make_super_kernel_gmm(params["stages"][0]["ffn"]["experts"], cfg)
    lo_k, _ = lm_forward(params, cfg, tokens, gmm=gmm)
    lo_e, _ = lm_forward(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(lo_k), np.asarray(lo_e), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------- flash attention

@pytest.mark.parametrize("BH,S,dh,bq,bk", [(4, 128, 64, 32, 32),
                                           (2, 256, 32, 64, 64),
                                           (1, 64, 128, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, S, dh, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, S, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (BH, S, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, S, dh)).astype(dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_non_power_of_two_seq():
    """S=192 with the default 128 blocks: min-clamp would misindex; the
    divisor rounding (96) must match the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (2, 192, 32)) for kk in ks)
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (32, 20.0)])
def test_flash_attention_window_softcap(window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 32)) for kk in ks)
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_mha_flash_gqa_layout():
    from repro.models.attention import dense_causal_attention
    cfg = ModelConfig(name="k", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, dtype=jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out = mha_flash(q, k, v, block_q=32, block_k=32)
    ref = dense_causal_attention(q, k, v, cfg, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# --------------------------------------------------------- dispatch/combine

@pytest.mark.parametrize("T,E,K", [(64, 8, 2), (128, 4, 4), (32, 16, 1)])
def test_kernel_dispatch_combine_vs_jnp(T, E, K):
    cfg = ModelConfig(name="k", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                      vocab_size=64, num_experts=E, top_k=K, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (T, cfg.d_model))
    router = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model, E))
    w, idx, _ = router_topk(router, x, cfg)
    xb_k, info_k = kernel_moe_dispatch(x, idx, cfg)
    xb_j, info_j = moe_dispatch(x, idx, cfg)
    np.testing.assert_array_equal(np.asarray(xb_k), np.asarray(xb_j))
    yb = xb_j * 3.0
    y_k = kernel_moe_combine(yb, info_k, w, T)
    y_j = moe_combine(yb, info_j, w, T)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), rtol=1e-6,
                               atol=1e-6)
