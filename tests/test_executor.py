"""Threaded disaggregated executor: asynchrony must not change the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import BatchJob, DisaggregatedExecutor
from repro.models.lm import init_lm_params, lm_backbone

# whole-module: threaded executor + jit compiles are the slowest unit tests.
# Deselect locally with `-m "not slow"`; tier-1 still runs everything.
pytestmark = pytest.mark.slow


def _setup(num_layers=3, num_experts=4, top_k=2, shared=0):
    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=num_layers, num_experts=num_experts, top_k=top_k,
        num_shared_experts=shared)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _jobs(cfg, n, B=2, S=8, seed=0):
    return [BatchJob(tokens=np.random.RandomState(seed + i).randint(
        0, cfg.vocab_size, (B, S)), bid=i) for i in range(n)]


def _check(done, params, cfg, tol=5e-5):
    for j in done:
        ref, _ = lm_backbone(params, cfg, jnp.asarray(j.tokens),
                             moe_mode="dense")
        np.testing.assert_allclose(np.asarray(j.result), np.asarray(ref),
                                   rtol=tol, atol=tol)


def test_async_pipeline_equals_sync_reference():
    cfg, params = _setup()
    jobs = _jobs(cfg, 4)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=4)
    done = ex.run([jobs[:2], jobs[2:]])
    _check(done, params, cfg)


def test_dual_batch_interleaving_off():
    cfg, params = _setup()
    jobs = _jobs(cfg, 2, seed=5)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=2, interleave=False)
    done = ex.run([jobs])
    _check(done, params, cfg)


def test_tp_rows_protocol():
    cfg, params = _setup()
    jobs = _jobs(cfg, 2, seed=9)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=2, T=2)
    done = ex.run([jobs[:1], jobs[1:]])
    _check(done, params, cfg)


def test_shared_expert_on_attention_device():
    cfg, params = _setup(shared=1)
    jobs = _jobs(cfg, 2, seed=11)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=2)
    done = ex.run([jobs])
    _check(done, params, cfg)


def test_out_of_order_moe_execution_observed():
    """With 2 groups x 2 batches the MoE log must show layer inversions."""
    cfg, params = _setup(num_layers=4)
    jobs = _jobs(cfg, 4, seed=3)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=2)
    ex.run([jobs[:2], jobs[2:]])
    layers = [ev[4] for ev in ex.log if ev[0] == "moe"]
    inversions = sum(1 for a, b in zip(layers, layers[1:]) if b < a)
    assert inversions > 0, "expected out-of-order MoE layer execution"
