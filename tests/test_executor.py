"""Threaded disaggregated executor: asynchrony must not change the math."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import ExpertLoadModel, Placement
from repro.core.executor import BatchJob, DisaggregatedExecutor
from repro.models.lm import init_lm_params, lm_backbone

# whole-module: threaded executor + jit compiles are the slowest unit tests.
# Deselect locally with `-m "not slow"`; tier-1 still runs everything.
pytestmark = pytest.mark.slow


def _setup(num_layers=3, num_experts=4, top_k=2, shared=0):
    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=num_layers, num_experts=num_experts, top_k=top_k,
        num_shared_experts=shared)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _jobs(cfg, n, B=2, S=8, seed=0):
    return [BatchJob(tokens=np.random.RandomState(seed + i).randint(
        0, cfg.vocab_size, (B, S)), bid=i) for i in range(n)]


def _check(done, params, cfg, tol=5e-5):
    for j in done:
        ref, _ = lm_backbone(params, cfg, jnp.asarray(j.tokens),
                             moe_mode="dense")
        np.testing.assert_allclose(np.asarray(j.result), np.asarray(ref),
                                   rtol=tol, atol=tol)


def test_async_pipeline_equals_sync_reference():
    cfg, params = _setup()
    jobs = _jobs(cfg, 4)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=4)
    done = ex.run([jobs[:2], jobs[2:]])
    _check(done, params, cfg)


def test_dual_batch_interleaving_off():
    cfg, params = _setup()
    jobs = _jobs(cfg, 2, seed=5)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=2, interleave=False)
    done = ex.run([jobs])
    _check(done, params, cfg)


def test_tp_rows_protocol():
    cfg, params = _setup()
    jobs = _jobs(cfg, 2, seed=9)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=2, T=2)
    done = ex.run([jobs[:1], jobs[1:]])
    _check(done, params, cfg)


def test_shared_expert_on_attention_device():
    cfg, params = _setup(shared=1)
    jobs = _jobs(cfg, 2, seed=11)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=2)
    done = ex.run([jobs])
    _check(done, params, cfg)


@pytest.mark.parametrize("policy", ["round_robin", "greedy_balanced",
                                    "replicated(2)"])
def test_fused_hot_path_contract_all_placements(policy):
    """The fused super-kernel path must preserve the dense-reference math
    under every placement policy (replica fan-out included)."""
    cfg, params = _setup(num_experts=8)
    jobs = _jobs(cfg, 2, seed=21)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=4,
                               placement=Placement.parse(policy),
                               moe_path="fused")
    done = ex.run([jobs[:1], jobs[1:]])
    _check(done, params, cfg)


def test_eager_fallback_contract():
    """--path eager (the pre-fusion baseline) stays correct, placement-routed."""
    cfg, params = _setup(num_experts=8)
    jobs = _jobs(cfg, 2, seed=23)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=4, moe_path="eager",
                               placement=Placement("replicated",
                                                   replicate_hot=2))
    done = ex.run([jobs])
    _check(done, params, cfg)


def test_executor_simulator_placement_parity():
    """The SAME Placement must yield the SAME expert→device (and replica
    fan-out) assignment in the real executor and in the simulator's
    ExpertLoadModel (ROADMAP item d)."""
    cfg, params = _setup(num_experts=8)
    E = 4
    for pl in (Placement(), Placement("greedy_balanced"),
               Placement("replicated", replicate_hot=2)):
        ex = DisaggregatedExecutor(params, cfg, D=1, E=E, placement=pl)
        lm = ExpertLoadModel(num_experts=cfg.num_experts, top_k=cfg.top_k,
                             ep=E, mode="uniform", placement=pl)
        assert ex.table == lm.placement_table(0)
        assert ex.dev_experts == pl.device_experts(ex.expert_fractions, E)
        # resident weight stacks follow the fan-out: a replicated expert is
        # resident on every one of its hosts
        for e, hosts in enumerate(ex.table):
            for d in hosts:
                assert e in ex.dev_experts[d]
    # measured (non-uniform) popularity flows through identically
    lmz = ExpertLoadModel(num_experts=cfg.num_experts, top_k=cfg.top_k, ep=E,
                          mode="layer", alpha=1.2,
                          placement=Placement("replicated", replicate_hot=2))
    fr = tuple(float(x) for x in lmz.expert_fractions(0))
    ex = DisaggregatedExecutor(params, cfg, D=1, E=E,
                               placement=lmz.placement, expert_fractions=fr)
    assert ex.table == lmz.placement_table(0)


def test_replica_routing_targets_hosts_and_spreads():
    cfg, params = _setup(num_experts=8)
    pl = Placement("replicated", replicate_hot=1)
    ex = DisaggregatedExecutor(params, cfg, D=1, E=4, placement=pl)
    hot = next(e for e, h in enumerate(ex.table) if len(h) > 1)
    dev = ex._route(np.full(64, hot))
    # hot-expert traffic spreads over exactly its replicas, evenly
    assert set(int(d) for d in dev) == set(ex.table[hot])
    counts = np.bincount(dev, minlength=4)[list(ex.table[hot])]
    assert counts.max() - counts.min() <= 1
    # single-host experts always go to their one host
    solo = next(e for e, h in enumerate(ex.table) if len(h) == 1)
    assert set(int(d) for d in ex._route(np.full(5, solo))) \
        == {ex.table[solo][0]}


@pytest.mark.parametrize("shared", [0, 1])
def test_combine_segsum_bitwise_equals_host_path(shared):
    """ROADMAP item (i): the jitted segment-sum combine must be BIT-equal
    with the np.add.at host path it replaces (same per-row products, same
    accumulation order), shared-expert add included."""
    cfg, params = _setup(num_experts=8, shared=shared)
    jobs = _jobs(cfg, 2, seed=41)
    fresh = lambda: [[BatchJob(tokens=j.tokens, bid=j.bid) for j in jobs]]
    ex_h = DisaggregatedExecutor(params, cfg, D=1, E=4, combine_path="host")
    ex_s = DisaggregatedExecutor(params, cfg, D=1, E=4, combine_path="segsum")
    done_h, done_s = ex_h.run(fresh()), ex_s.run(fresh())
    for a, b in zip(done_h, done_s):
        np.testing.assert_array_equal(np.asarray(a.result),
                                      np.asarray(b.result))
    assert ex_s.trace_counts.get("combine", 0) >= 1  # the jit really ran
    assert ex_h.trace_counts.get("combine", 0) == 0
    _check(done_s, params, cfg)


def test_live_apply_placement_preserves_contract():
    """ISSUE 5: re-placing experts on a live executor (quiesce, weight-slice
    copy, dispatch-table swap) must not change the math — and the migration
    must be accounted."""
    cfg, params = _setup(num_experts=8)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=4)
    jobs1 = _jobs(cfg, 2, seed=51)
    _check(ex.run([jobs1[:1], jobs1[1:]]), params, cfg)
    rec = ex.apply_placement(Placement("replicated", replicate_hot=2))
    assert rec["moved_copies"] > 0 and rec["bytes"] > 0
    assert ex.migrations == [rec] and ex.migrated_bytes == rec["bytes"]
    assert ex.table == Placement("replicated", replicate_hot=2).table(
        ex.expert_fractions, 4)
    jobs2 = _jobs(cfg, 2, seed=52)
    _check(ex.run([jobs2[:1], jobs2[1:]]), params, cfg)
    # a no-op re-placement (same table) moves nothing but is still logged,
    # so executed plans and the migration log stay one-to-one
    rec2 = ex.apply_placement(ex.placement)
    assert rec2["moved_copies"] == 0 and rec2["bytes"] == 0.0
    assert ex.migrations == [rec, rec2]
    assert ex.migrated_bytes == rec["bytes"]


def test_jit_cache_stable_after_warmup():
    """After one warmup run, a full multi-layer multi-batch run performs ZERO
    new traces — including the interleave=True dual-slot path (dispatch
    bubble criterion, paper Fig 10)."""
    cfg, params = _setup(num_layers=4)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=2, interleave=True)
    jobs = _jobs(cfg, 4, seed=31)
    # pre-warm the attention trace single-threaded (two group threads racing
    # the same first compile could legitimately trace twice)
    from repro.models.lm import embed_tokens
    h0 = embed_tokens(params, jnp.asarray(jobs[0].tokens), None, cfg)
    ex._attn_step(jnp.asarray(0, jnp.int32), h0)
    assert ex.trace_counts["attn"] == 1
    # same token arrays both runs: identical routing -> identical capacity
    # buckets, so ANY second-run trace is a genuine cache miss
    fresh = lambda: [[BatchJob(tokens=j.tokens, bid=j.bid) for j in jobs[:2]],
                     [BatchJob(tokens=j.tokens, bid=j.bid) for j in jobs[2:]]]
    ex.run(fresh())
    warm = dict(ex.trace_counts)
    assert warm["attn"] == 1  # one trace serves all layers x slots x batches
    assert warm.get("moe", 0) >= 1
    done = ex.run(fresh())
    assert dict(ex.trace_counts) == warm, "steady state must not retrace"
    _check(done, params, cfg)


def test_run_raises_on_hung_group_thread(monkeypatch):
    """A hung group thread must raise (with thread state), not silently
    return jobs with result=None."""
    cfg, params = _setup()
    ex = DisaggregatedExecutor(params, cfg, D=1, E=2)
    monkeypatch.setattr(DisaggregatedExecutor, "_group_worker",
                        lambda self, g: time.sleep(30))
    with pytest.raises(TimeoutError, match="group-0"):
        ex.run([_jobs(cfg, 1)], timeout=0.3)
    # the hung thread still shares our buffers: reuse must refuse, not race
    with pytest.raises(RuntimeError, match="timed-out run"):
        ex.run([_jobs(cfg, 1)], timeout=0.3)


def test_out_of_order_moe_execution_observed():
    """With 2 groups x 2 batches the MoE log must show layer inversions."""
    cfg, params = _setup(num_layers=4)
    jobs = _jobs(cfg, 4, seed=3)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=2)
    ex.run([jobs[:2], jobs[2:]])
    layers = [ev[4] for ev in ex.log if ev[0] == "moe"]
    inversions = sum(1 for a, b in zip(layers, layers[1:]) if b < a)
    assert inversions > 0, "expected out-of-order MoE layer execution"
