"""Cost-model properties reproducing the paper's §2.2 characterization.

Formerly hypothesis property tests; rewritten as seeded numpy.random sweeps
(hypothesis is not available in the pinned environment — ISSUE 1)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import CostModel, Deployment, ExpertLoadModel

CM = CostModel(get_config("deepseek_v32"), dep=Deployment(D=4, T=4, E=16))


@pytest.mark.parametrize("seed", range(6))
def test_attention_quadratic_scaling(seed):
    """Paper Fig 3a: prefill attention latency ~ s^2 once the quadratic core
    dominates the linear projections (s >= 16k for this geometry)."""
    rng = np.random.default_rng(seed)
    for s in rng.integers(16_384, 65_536, size=5):
        s = int(s)
        l1 = CM.attention_layer_latency([s])
        l2 = CM.attention_layer_latency([2 * s])
        assert 2.6 < l2 / l1 < 4.2


def test_attention_superlinear_everywhere():
    for s in (1024, 4096, 16_384):
        assert CM.attention_layer_latency([2 * s]) \
            > 1.9 * CM.attention_layer_latency([s])


def test_batch_of_equal_total_tokens_differs():
    """Paper Fig 4: 32k as 1x32k vs 32x1k differs by multiples (sum of
    squares, not square of sum)."""
    one_big = CM.attention_layer_latency([32_768])
    many_small = CM.attention_layer_latency([1024] * 32)
    assert one_big / many_small > 2.0


@pytest.mark.parametrize("seed", range(10))
def test_attention_latency_superadditive(seed):
    """Merging requests into one batch is never slower than the sum of the
    quadratic parts would suggest: latency(batch) <= sum latency(singletons)."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 11))
    lens = [int(x) for x in rng.integers(64, 8193, size=n)]
    merged = CM.attention_layer_latency(lens)
    split = sum(CM.attention_layer_latency([l]) for l in lens)
    assert merged <= split * 1.01


def test_dispatch_bytes_deduped():
    """Per-token payload <= K copies, >= 1 copy (distinct-device dedup)."""
    t = 1000
    b = CM.dispatch_bytes(t)
    per_token = b / t / (CM.cfg.d_model * 2)
    assert 1.0 <= per_token <= CM.cfg.top_k


def test_async_dispatch_faster_than_sync_p2p():
    """Paper Fig 14: sync P2P is ~4-6x slower; grows with busy receivers."""
    for tokens in (512, 1024, 8192):
        a = CM.async_dispatch_latency(tokens)
        s = CM.sync_p2p_dispatch_latency(tokens)
        assert s / a > 2.0
        s_busy = CM.sync_p2p_dispatch_latency(tokens, receiver_busy=1e-3)
        assert s_busy > s


def test_moe_latency_monotone():
    prev = 0.0
    for t in (1, 100, 1000, 10_000, 100_000):
        cur = CM.moe_layer_latency(t)
        assert cur >= prev
        prev = cur


# ----------------------------------------------------------------------
# Per-device expert-parallel model (ISSUE 1 tentpole)
# ----------------------------------------------------------------------


def _load_model(mode="uniform", alpha=0.0, seed=0):
    c = CM.cfg
    return ExpertLoadModel(num_experts=c.num_experts, top_k=c.top_k,
                           ep=CM.dep.E, mode=mode, alpha=alpha, seed=seed)


@pytest.mark.parametrize("tokens", [100, 1000, 8192, 32_768])
def test_uniform_per_device_matches_aggregate(tokens):
    """skew=0: the slowest (== every) device reproduces the seed aggregate
    moe_layer_latency exactly — the per-device refactor is a strict
    generalization of the old single-server model."""
    lm = _load_model()
    lat = CM.moe_device_latency(lm.device_loads(tokens),
                                lm.device_experts_hit(tokens), tokens)
    agg = CM.moe_layer_latency(tokens)
    assert lat.shape == (CM.dep.E,)
    np.testing.assert_allclose(lat, agg, rtol=1e-12)


@pytest.mark.parametrize("seed", range(4))
def test_device_fractions_are_distributions(seed):
    rng = np.random.default_rng(seed)
    for mode in ("uniform", "zipf", "layer"):
        alpha = float(rng.uniform(0.3, 2.0))
        lm = _load_model(mode, alpha, seed)
        for layer in (0, 1, 7):
            f = lm.device_fractions(layer)
            assert f.shape == (CM.dep.E,)
            assert abs(f.sum() - 1.0) < 1e-9
            assert (f >= 0).all()


def test_zipf_skew_increases_straggler_latency():
    """The hottest device under Zipf routing is strictly slower than uniform
    once past the memory-bound plateau, and skew is monotone in alpha."""
    tokens = 16_384
    uni = CM.moe_device_latency(
        _load_model().device_loads(tokens),
        _load_model().device_experts_hit(tokens), tokens).max()
    prev = uni
    for alpha in (0.6, 1.0, 1.4):
        lm = _load_model("zipf", alpha)
        worst = CM.moe_device_latency(lm.device_loads(tokens),
                                      lm.device_experts_hit(tokens),
                                      tokens).max()
        assert worst > prev * 1.0001, alpha
        prev = worst


def test_layer_mode_is_layer_correlated():
    """mode='layer': same hot devices on every layer; mode='zipf': hot-expert
    identity is redrawn per layer."""
    corr = _load_model("layer", 1.2)
    dec = _load_model("zipf", 1.2)
    np.testing.assert_allclose(corr.device_fractions(0),
                               corr.device_fractions(5))
    assert not np.allclose(dec.device_fractions(0), dec.device_fractions(5))


def test_layer_matrices_shapes_and_consistency():
    L, tokens = 8, 4096
    for mode in ("uniform", "zipf", "layer"):
        lm = _load_model(mode, 1.0)
        loads = lm.layer_device_loads(tokens, L)
        hits = lm.layer_device_hits(tokens, L)
        hot = lm.layer_hot_factors(L)
        assert loads.shape == hits.shape == (L, CM.dep.E)
        assert hot.shape == (L,)
        assert (hot >= 1.0 - 1e-9).all()
        np.testing.assert_allclose(loads.sum(axis=1),
                                   tokens * CM.cfg.top_k, rtol=1e-9)


def test_skewed_inflection_is_earlier():
    """The hottest device goes compute-bound at fewer aggregate tokens, so
    the batcher's inflection target shrinks under skew."""
    lm = _load_model("zipf", 1.2)
    assert lm.hot_fraction() > 1.0 / CM.dep.E
    skewed = CM.moe_inflection_tokens(lm.hot_fraction())
    uniform = CM.moe_inflection_tokens()
    assert skewed < uniform
    assert CM.moe_inflection_tokens(1.0 / CM.dep.E) == uniform
