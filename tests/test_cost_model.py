"""Cost-model properties reproducing the paper's §2.2 characterization."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.core.cost_model import CostModel, Deployment

CM = CostModel(get_config("deepseek_v32"), dep=Deployment(D=4, T=4, E=16))


@given(st.integers(min_value=16_384, max_value=65_536))
@settings(max_examples=30, deadline=None)
def test_attention_quadratic_scaling(s):
    """Paper Fig 3a: prefill attention latency ~ s^2 once the quadratic core
    dominates the linear projections (s >= 16k for this geometry)."""
    l1 = CM.attention_layer_latency([s])
    l2 = CM.attention_layer_latency([2 * s])
    assert 2.6 < l2 / l1 < 4.2


def test_attention_superlinear_everywhere():
    for s in (1024, 4096, 16_384):
        assert CM.attention_layer_latency([2 * s]) \
            > 1.9 * CM.attention_layer_latency([s])


def test_batch_of_equal_total_tokens_differs():
    """Paper Fig 4: 32k as 1x32k vs 32x1k differs by multiples (sum of
    squares, not square of sum)."""
    one_big = CM.attention_layer_latency([32_768])
    many_small = CM.attention_layer_latency([1024] * 32)
    assert one_big / many_small > 2.0


@given(st.lists(st.integers(min_value=64, max_value=8192), min_size=2,
                max_size=10))
@settings(max_examples=30, deadline=None)
def test_attention_latency_superadditive(lens):
    """Merging requests into one batch is never slower than the sum of the
    quadratic parts would suggest: latency(batch) <= sum latency(singletons)."""
    merged = CM.attention_layer_latency(lens)
    split = sum(CM.attention_layer_latency([l]) for l in lens)
    assert merged <= split * 1.01


def test_dispatch_bytes_deduped():
    """Per-token payload <= K copies, >= 1 copy (distinct-device dedup)."""
    t = 1000
    b = CM.dispatch_bytes(t)
    per_token = b / t / (CM.cfg.d_model * 2)
    assert 1.0 <= per_token <= CM.cfg.top_k


def test_async_dispatch_faster_than_sync_p2p():
    """Paper Fig 14: sync P2P is ~4-6x slower; grows with busy receivers."""
    for tokens in (512, 1024, 8192):
        a = CM.async_dispatch_latency(tokens)
        s = CM.sync_p2p_dispatch_latency(tokens)
        assert s / a > 2.0
        s_busy = CM.sync_p2p_dispatch_latency(tokens, receiver_busy=1e-3)
        assert s_busy > s


def test_moe_latency_monotone():
    prev = 0.0
    for t in (1, 100, 1000, 10_000, 100_000):
        cur = CM.moe_layer_latency(t)
        assert cur >= prev
        prev = cur
