"""kernelcheck + shardcheck (ISSUE 7): every rule catches its seeded fixture
violation, the good fixtures and the repo's own src/ stay clean, and
stale-suppression detection only fires under --strict-suppressions."""
import os

import pytest

from repro.analysis import run_static

HERE = os.path.dirname(__file__)
FIX = os.path.join(HERE, "fixtures", "analysis")
SRC = os.path.join(HERE, "..", "src", "repro")


def rule_set(result):
    return {f.rule for f in result.unsuppressed}


# ---------------------------------------------------------------------------
# kernelcheck — each rule catches a seeded violation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bad_kernels():
    return run_static([os.path.join(FIX, "bad_kernels.py")])


def test_catches_index_map_arity(bad_kernels):
    hits = bad_kernels.by_rule("kc-index-map-arity")
    assert hits and any("grid rank 4" in f.message for f in hits)


def test_catches_block_rank(bad_kernels):
    hits = bad_kernels.by_rule("kc-block-rank")
    # both flavors: index_map return vs block shape, out_specs vs out_shape
    assert any("coordinate" in f.message for f in hits)
    assert any("out_shape" in f.message for f in hits)


def test_catches_min_clamp(bad_kernels):
    hits = bad_kernels.by_rule("kc-min-clamp")
    # bc, bn, bk: the plain and the tuple-assignment form
    assert {m for f in hits for m in ("bc", "bn", "bk") if f"`{m}`"
            in f.message} == {"bc", "bn", "bk"}
    assert all("floor_to_divisor" in f.message for f in hits)


def test_catches_missing_accum_init(bad_kernels):
    hits = [f for f in bad_kernels.unsuppressed
            if f.rule == "kc-accum-init"]
    assert hits and any("o_ref" in f.message for f in hits)


def test_catches_dot_without_preferred_type(bad_kernels):
    hits = bad_kernels.by_rule("kc-dot-preferred-type")
    # both flavors: kwarg missing entirely, and set to a non-f32 dtype
    assert any("without preferred_element_type" in f.message for f in hits)
    assert any("must accumulate in f32" in f.message for f in hits)


def test_catches_unused_scalar_prefetch(bad_kernels):
    hits = bad_kernels.by_rule("kc-unused-scalar-prefetch")
    assert hits and any("slot_ref" in f.message for f in hits)


def test_kernel_ok_suppression_and_empty_reason(bad_kernels):
    sup = [f for f in bad_kernels.suppressed if f.rule == "kc-accum-init"]
    assert sup and sup[0].reason.startswith("gauge kernel")
    assert sup[0].suppress_line is not None
    assert bad_kernels.by_rule("kernel-ok-no-reason")


def test_good_kernels_clean():
    res = run_static([os.path.join(FIX, "good_kernels.py")])
    assert res.unsuppressed == []


# ---------------------------------------------------------------------------
# shardcheck — each rule catches a seeded violation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bad_shard():
    return run_static([os.path.join(FIX, "bad_shard.py")])


def test_catches_unknown_mesh_axis(bad_shard):
    hits = bad_shard.by_rule("sc-unknown-mesh-axis")
    assert any("'modle'" in f.message for f in hits if not f.suppressed)


def test_catches_duplicate_mesh_axis(bad_shard):
    hits = bad_shard.by_rule("sc-duplicate-mesh-axis")
    assert hits and "'data'" in hits[0].message


def test_catches_spec_rank(bad_shard):
    hits = bad_shard.by_rule("sc-spec-rank")
    assert hits and "3 entries for a rank-2 array" in hits[0].message


def test_catches_fsdp_unknown_arch(bad_shard):
    hits = bad_shard.by_rule("sc-fsdp-unknown-arch")
    assert hits and "'ghost-arch-9000'" in hits[0].message


def test_catches_unknown_logical_axis(bad_shard):
    hits = bad_shard.by_rule("sc-unknown-logical-axis")
    assert hits and "'heds'" in hits[0].message


def test_catches_f64_in_jitted_code(bad_shard):
    assert bad_shard.by_rule("sc-f64-literal")


def test_catches_bf16_accumulator(bad_shard):
    hits = bad_shard.by_rule("sc-bf16-accum")
    assert hits and "`acc`" in hits[0].message


def test_shard_ok_suppression(bad_shard):
    sup = [f for f in bad_shard.suppressed
           if f.rule == "sc-unknown-mesh-axis"]
    assert sup and "'rows'" in sup[0].message
    assert sup[0].reason.startswith("deliberate host-only spec")


def test_good_shard_clean():
    res = run_static([os.path.join(FIX, "good_shard.py")])
    assert res.unsuppressed == []


# ---------------------------------------------------------------------------
# stale-suppression detection (--strict-suppressions)
# ---------------------------------------------------------------------------


def test_stale_suppression_flagged_only_in_strict_mode():
    path = os.path.join(FIX, "stale_suppress.py")
    assert run_static([path]).unsuppressed == []
    strict = run_static([path], strict_suppressions=True)
    hits = strict.by_rule("stale-suppression")
    assert len(hits) == 1 and "race-ok" in hits[0].message


def test_used_suppressions_not_stale():
    """bad_kernels' kernel-ok suppression IS consumed — strict mode must
    not flag it (only the empty-reason one is dead by construction)."""
    strict = run_static([os.path.join(FIX, "bad_kernels.py")],
                        strict_suppressions=True)
    stale = strict.by_rule("stale-suppression")
    assert all("gauge kernel" not in f.message for f in stale)


# ---------------------------------------------------------------------------
# the repo itself stays clean (with suppressions justified)
# ---------------------------------------------------------------------------


def test_repo_src_clean_strict():
    res = run_static([SRC], strict_suppressions=True)
    assert res.unsuppressed == [], \
        "\n".join(f.format() for f in res.unsuppressed)
    assert all(f.reason for f in res.suppressed)
