"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one train step +
prefill + decode on CPU, asserting output shapes and finiteness."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, EXTRA_ARCHS, get_config
from repro.models.api import build_api


@pytest.mark.parametrize("arch", ARCHS + EXTRA_ARCHS)
def test_arch_smoke(arch):
    cfg = get_config(arch).smoke()
    api = build_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)

    # --- one training step's loss + grads exist and are finite
    batch = api.make_batch(key, 64, 2, "train")
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    # --- prefill: last-position logits + caches
    pb = api.make_batch(key, 64, 2, "prefill")
    logits, caches = jax.jit(api.prefill)(params, pb)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # --- one decode step consuming the prefill caches
    db = api.make_batch(key, 64, 2, "decode")
    logits2, caches2 = jax.jit(api.decode)(params, caches, db)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_constructible(arch):
    """Full-size config param tree is well-formed (eval_shape, no allocation)."""
    cfg = get_config(arch)
    api = build_api(cfg)
    tree = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
    assert n > 1e8, f"{arch}: implausibly small param count {n}"


def test_train_step_decreases_loss_smoke():
    """A few steps of real training on the copy task reduce loss (MoE arch)."""
    from repro.data.pipeline import pipeline_for
    from repro.launch.steps import TrainState, build_train_step
    from repro.optim.adamw import AdamW

    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=2, num_experts=4, top_k=2)
    api = build_api(cfg)
    opt = AdamW(lr=1e-3)
    params = api.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params))
    step_fn = jax.jit(build_train_step(api, opt))
    pipe = pipeline_for(cfg, 32, 4)
    losses = []
    for s in range(8):
        state, metrics = step_fn(state, pipe.batch(s))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
