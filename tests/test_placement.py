"""Expert placement, hot-expert replication & device failover (ISSUE 2).

Placement invariants the tentpole's refactor must preserve:
  * round_robin reproduces the PR-1 hard-coded fractions BIT-exactly,
  * replicated(k) lowers the hot fraction monotonically in k,
  * every expert stays hosted through failures (replica failover + orphan
    re-placement), and dead devices host nothing.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import (CostModel, Deployment, ExpertLoadModel,
                                   Placement)
from repro.core.simulator import SimConfig

CFG = get_config("deepseek_v32")
EP = 16


def _lm(mode="zipf", alpha=1.2, placement=Placement(), seed=0):
    return ExpertLoadModel(num_experts=CFG.num_experts, top_k=CFG.top_k,
                           ep=EP, mode=mode, alpha=alpha, seed=seed,
                           placement=placement)


# ---------------------------------------------------------------------------
# round_robin == PR-1, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,alpha", [("uniform", 0.0), ("zipf", 0.6),
                                        ("zipf", 1.2), ("layer", 1.2)])
def test_round_robin_fractions_bit_exact_with_pr1(mode, alpha):
    """The default Placement must reproduce the formerly hard-coded
    round-robin scatter np.add.at(dev, arange(n) % ep, p) bit-exactly."""
    lm = _lm(mode, alpha)
    for layer in (0, 3):
        p = lm.expert_fractions(layer if mode == "zipf" else 0)
        dev = np.zeros(EP)
        np.add.at(dev, np.arange(len(p)) % EP, p)
        assert np.array_equal(dev, lm.device_fractions(layer))
        a = 4096.0 * lm.top_k
        hit = 1.0 - np.power(np.clip(1.0 - p, 0.0, 1.0), a)
        devh = np.zeros(EP)
        np.add.at(devh, np.arange(len(p)) % EP, hit)
        assert np.array_equal(devh, lm.device_experts_hit(4096, layer))


def test_round_robin_keeps_seed_dispatch_copies():
    """With the default placement the CostModel keeps its closed-form
    dispatch fan-out (copies_override is only set for other placements)."""
    cm = CostModel(CFG, dep=Deployment(D=4, T=4, E=16))
    lm = _lm("uniform", 0.0)
    closed = 16 * (1.0 - (1.0 - 1.0 / 16) ** CFG.top_k)
    assert lm.expected_copies() == pytest.approx(closed, rel=1e-12)
    assert cm.dispatch_bytes(1000) == pytest.approx(
        1000 * closed * CFG.d_model * 2, rel=1e-12)


# ---------------------------------------------------------------------------
# replication & balancing
# ---------------------------------------------------------------------------


def test_replication_lowers_hot_fraction_monotonically():
    prev = None
    hf = {}
    for k in (0, 1, 2, 4, 8):
        hf[k] = _lm(placement=Placement("replicated",
                                        replicate_hot=k)).hot_fraction()
        assert prev is None or hf[k] <= prev + 1e-12, k
        prev = hf[k]
    assert hf[8] < hf[0] * 0.5  # replication substantially flattens the peak
    assert hf[0] == _lm().hot_fraction()  # k=0 == plain round_robin base


def test_replicated_splits_load_across_hosts():
    lm = _lm(placement=Placement("replicated", replicate_hot=2))
    table = lm.placement_table(0)
    p = lm.expert_fractions(0)
    hot = int(np.argmax(p))
    assert len(table[hot]) >= 2  # the hottest expert has replicas
    assert len(set(table[hot])) == len(table[hot])  # on distinct devices
    f = lm.device_fractions(0)
    assert abs(f.sum() - 1.0) < 1e-9  # load split, not duplicated


def test_greedy_balanced_no_worse_hot_fraction_than_round_robin():
    for alpha in (0.6, 1.2):
        rr = _lm(alpha=alpha).hot_fraction()
        gb = _lm(alpha=alpha,
                 placement=Placement("greedy_balanced")).hot_fraction()
        assert gb <= rr + 1e-12, alpha


def test_fractions_remain_distributions_under_all_policies():
    for pl in (Placement(), Placement("greedy_balanced"),
               Placement("replicated", replicate_hot=4),
               Placement("replicated", replicate_hot=4, dead=(5,))):
        lm = _lm(placement=pl)
        for layer in (0, 2):
            f = lm.device_fractions(layer)
            assert f.shape == (EP,)
            assert abs(f.sum() - 1.0) < 1e-9
            assert (f >= 0).all()


# ---------------------------------------------------------------------------
# device failure / failover
# ---------------------------------------------------------------------------


def test_failed_device_hosts_nothing_and_experts_survive():
    for base in (Placement(), Placement("replicated", replicate_hot=2)):
        lm = _lm(placement=base).with_failed(3)
        for layer in (0, 1):
            table = lm.placement_table(layer)
            assert len(table) == CFG.num_experts
            assert all(len(h) >= 1 for h in table)  # every expert hosted
            assert all(3 not in h for h in table)  # dead hosts nothing
            assert lm.device_fractions(layer)[3] == 0.0


def test_replica_failover_preserves_surviving_hosts():
    """Killing one host of a replicated expert consolidates its load onto the
    surviving replicas (no re-placement)."""
    lm = _lm(placement=Placement("replicated", replicate_hot=1))
    p = lm.expert_fractions(0)
    hot = int(np.argmax(p))
    hosts = lm.placement_table(0)[hot]
    dead = hosts[0]
    survivors = [d for d in hosts if d != dead]
    after = lm.with_failed(dead).placement_table(0)[hot]
    assert list(after) == survivors


def test_placement_parse_and_resolution():
    assert Placement.parse("round_robin") == Placement()
    assert Placement.parse("replicated(3)") == \
        Placement("replicated", replicate_hot=3)
    assert Placement.parse("replicated").replicate_hot == 2  # default k
    with pytest.raises(ValueError):
        Placement.parse("nonsense")
    # SimConfig: --replicate-hot alone promotes the (default) policy
    assert SimConfig(replicate_hot=2).resolved_placement() == \
        Placement("replicated", replicate_hot=2)
    assert SimConfig(placement="replicated(4)").resolved_placement() \
        .replicate_hot == 4
    assert SimConfig().resolved_placement() == Placement()
    # ...but conflicts with an explicitly different policy instead of
    # silently rewriting it
    with pytest.raises(ValueError):
        SimConfig(placement="greedy_balanced",
                  replicate_hot=2).resolved_placement()


def test_device_experts_is_inverse_of_table():
    """The executor-facing per-device view must agree with the per-expert
    host table on every policy (this is what keeps the REAL executor and the
    simulator on the same expert→device assignment — ROADMAP item d)."""
    fr = Placement.uniform_fractions(CFG.num_experts)
    assert sum(fr) == pytest.approx(1.0)
    for pl in (Placement(), Placement("greedy_balanced"),
               Placement("replicated", replicate_hot=3),
               Placement("replicated", replicate_hot=3, dead=(2,))):
        table = pl.table(fr, EP)
        held = pl.device_experts(fr, EP)
        assert len(held) == EP
        for e, hosts in enumerate(table):
            for d in range(EP):
                assert (e in held[d]) == (d in hosts)
        for d in pl.dead:
            assert held[d] == ()


def test_device_experts_round_robin_uniform():
    fr = Placement.uniform_fractions(8)
    held = Placement().device_experts(fr, 4)
    assert held == ((0, 4), (1, 5), (2, 6), (3, 7))


def test_expected_copies_tracks_placement():
    """Replicas add dispatch targets; a dead device removes one."""
    rr = _lm()
    rep = _lm(placement=Placement("replicated", replicate_hot=4))
    assert rep.expected_copies() > rr.expected_copies()
    dead = _lm(placement=Placement(dead=(0,)))
    assert dead.expected_copies() < rr.expected_copies() + 1e-9
