"""HLO cost contracts (ISSUE 7): the tolerance-band diff catches synthetic
FLOP/byte inflation against a perturbed golden, the checked-in goldens are
well-formed, and a fresh compile of every pinned cell still matches them
(subprocess: the forced-device XLA flag must precede the jax import, and
conftest deliberately keeps this process single-device)."""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis.contracts import (CONTRACTS, METRICS, RTOL, diff_metrics,
                                      load_golden)

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


# ---------------------------------------------------------------------------
# diff_metrics — the gate logic, pure
# ---------------------------------------------------------------------------


GOLD = {"dot_flops": 1e9, "collective_bytes": 2e7, "memory_bytes": 5e9}


def test_within_band_passes():
    measured = {k: v * 1.01 for k, v in GOLD.items()}
    assert diff_metrics(GOLD, measured) == []


def test_inflation_fails():
    measured = dict(GOLD, collective_bytes=GOLD["collective_bytes"] * 1.5)
    v = diff_metrics(GOLD, measured)
    assert len(v) == 1 and v[0]["metric"] == "collective_bytes"
    assert v[0]["why"] == "inflated" and v[0]["rel"] > 0.4


def test_deflation_fails_too():
    # a drop means the golden is stale — re-baseline deliberately
    measured = dict(GOLD, dot_flops=GOLD["dot_flops"] * 0.5)
    v = diff_metrics(GOLD, measured)
    assert len(v) == 1 and v[0]["why"] == "deflated"


def test_missing_metric_fails():
    measured = {k: v for k, v in GOLD.items() if k != "memory_bytes"}
    v = diff_metrics(GOLD, measured)
    assert len(v) == 1 and v[0]["why"] == "metric missing"


def test_perturbed_checked_in_golden_fails():
    """The pinned synthetic-inflation case: take a REAL golden, inflate each
    metric past the band, and assert the gate trips on exactly that metric."""
    golden = load_golden("moe_train")
    assert golden is not None, "run `python -m repro.analysis --update-contracts`"
    for metric in METRICS:
        bad = dict(golden["metrics"])
        bad[metric] = bad[metric] * (1 + 2 * RTOL)
        v = diff_metrics(golden["metrics"], bad)
        assert [x["metric"] for x in v] == [metric]


# ---------------------------------------------------------------------------
# goldens — well-formed and complete
# ---------------------------------------------------------------------------


def test_goldens_checked_in_and_wellformed():
    for spec in CONTRACTS:
        golden = load_golden(spec.name)
        assert golden is not None, spec.name
        assert golden["arch"] == spec.arch and golden["kind"] == spec.kind
        for metric in METRICS:
            assert golden["metrics"][metric] > 0, (spec.name, metric)
    # the MoE cells must actually exercise the network, or the contract
    # could never catch a communication-volume regression
    moe = load_golden("moe_train")
    assert moe["metrics"]["collective_bytes"] > 1e6


# ---------------------------------------------------------------------------
# fresh dryrun matches the goldens (one compile pass, own process)
# ---------------------------------------------------------------------------


def test_fresh_compile_matches_goldens():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.analysis import contracts as C

        mesh = C._make_mesh()
        for spec in C.CONTRACTS:
            golden = C.load_golden(spec.name)
            assert golden is not None, spec.name
            measured = C.measure(spec, mesh)
            v = C.diff_metrics(golden["metrics"], measured,
                               rtol=golden.get("rtol", C.RTOL))
            assert not v, (spec.name, v)
            # and a synthetically inflated golden must trip on the SAME
            # fresh measurement (end-to-end pin of the CI failure mode)
            bad = {k: x * 1.5 for k, x in golden["metrics"].items()}
            v = C.diff_metrics(bad, measured)
            assert len(v) == len(C.METRICS), (spec.name, v)
            print(spec.name, "ok")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for spec in CONTRACTS:
        assert f"{spec.name} ok" in proc.stdout
