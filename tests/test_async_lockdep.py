"""Seeded multi-sender/multi-receiver stress over the §3.2 buffer protocol,
run UNDER the runtime lockdep sanitizer (ISSUE 6 satellite): the bitmap
handshake must hold up against adversarial interleavings with every
repo-created lock instrumented — no order inversion, no held-lock wait, no
lost or duplicated payload."""
import threading
import time

import numpy as np
import pytest

from repro.analysis import lockdep
from repro.core.async_primitives import (AttnDeviceBuffer, CombinePayload,
                                         DispatchPayload, MoEDeviceBuffer)

SEED = 20260806


def _payload(dp_i, tp_j, rnd, layer=0, slot=0):
    tok = rnd.standard_normal((2, 4)).astype(np.float32)
    return DispatchPayload(layer=layer, slot=slot,
                           counts=np.array([2]),
                           tokens=tok,
                           token_ids=np.array([dp_i, tp_j], np.int64),
                           expert_ids=np.zeros(2, np.int64))


def test_moe_buffer_stress_multi_sender_multi_receiver():
    """D*T senders fan into E MoE buffers; E receiver threads drain regions
    out of order.  Every (round, dp, tp) payload must arrive exactly once at
    every device, and lockdep must stay silent."""
    D, T, E, ROUNDS = 3, 4, 2, 25
    with lockdep.lockdep_active(raise_on_violation=True):
        bufs = [MoEDeviceBuffer(D, T) for _ in range(E)]
        stop = threading.Event()
        got = [[] for _ in range(E)]  # receiver-private, no lock needed
        errors = []

        def sender(dp_i, tp_j):
            rnd = np.random.default_rng(SEED + dp_i * 100 + tp_j)
            try:
                for r in range(ROUNDS):
                    for e in range(E):
                        bufs[e].dispatch_send(
                            dp_i, tp_j, _payload(dp_i, tp_j, rnd, layer=r))
            except BaseException as ex:
                errors.append(ex)
                stop.set()

        def receiver(e):
            try:
                need = D * ROUNDS
                while len(got[e]) < need:
                    i = bufs[e].wait_any(timeout=30.0, stop=stop)
                    if i is None:
                        if stop.is_set():
                            return
                        raise TimeoutError(f"receiver {e} starved")
                    rows = bufs[e].dispatch_recv(i)
                    assert len(rows) == T
                    assert all(r is not None for r in rows)
                    got[e].append((i, [r.layer for r in rows]))
            except BaseException as ex:
                errors.append(ex)
                stop.set()

        threads = [threading.Thread(target=sender, args=(i, j))
                   for i in range(D) for j in range(T)]
        threads += [threading.Thread(target=receiver, args=(e,))
                    for e in range(E)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert errors == [], errors
        for e in range(E):
            # every device saw every region exactly ROUNDS times, and each
            # drained region was round-coherent (all T rows from one round:
            # backpressure serializes a sender's rounds per region)
            assert len(got[e]) == D * ROUNDS
            per_region = [0] * D
            for i, layers in got[e]:
                per_region[i] += 1
                assert len(set(layers)) == 1, layers
            assert per_region == [ROUNDS] * D
        assert lockdep.violations() == []
    lockdep.reset()


def test_combine_stress_and_roundtrip():
    """E MoE senders combine into per-(group, slot) attention buffers while
    receivers run combine_recv concurrently — the full dispatch/combine
    round trip under instrumentation."""
    E, GROUPS, ROUNDS = 4, 2, 10
    with lockdep.lockdep_active(raise_on_violation=True):
        bufs = [AttnDeviceBuffer(E) for _ in range(GROUPS)]
        errors = []

        def sender(e):
            rnd = np.random.default_rng(SEED + e)
            try:
                for r in range(ROUNDS):
                    for g in range(GROUPS):
                        bufs[g].combine_send(e, CombinePayload(
                            layer=r, token_ids=np.arange(2),
                            expert_ids=np.full(2, e),
                            outputs=rnd.standard_normal((2, 4))))
            except BaseException as ex:
                errors.append(ex)

        def receiver(g):
            try:
                for r in range(ROUNDS):
                    segs = bufs[g].combine_recv(timeout=30.0)
                    assert len(segs) == E
                    assert sorted(int(s.expert_ids[0]) for s in segs) \
                        == list(range(E))
                    assert {s.layer for s in segs} == {r}
            except BaseException as ex:
                errors.append(ex)

        threads = [threading.Thread(target=sender, args=(e,))
                   for e in range(E)]
        threads += [threading.Thread(target=receiver, args=(g,))
                    for g in range(GROUPS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert errors == [], errors
        assert lockdep.violations() == []
    lockdep.reset()


def test_backpressure_timeout_under_lockdep():
    """An undrained region must stall the sender (bounded by timeout) — the
    protocol's only blocking point — and the stall itself must not register
    as a lockdep violation (it holds no other lock while waiting)."""
    with lockdep.lockdep_active(raise_on_violation=True):
        buf = MoEDeviceBuffer(D=1, T=1)
        buf.dispatch_send(0, 0, _payload(0, 0, np.random.default_rng(SEED)))
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            buf.dispatch_send(0, 0,
                              _payload(0, 0, np.random.default_rng(SEED)),
                              timeout=0.2)
        assert time.monotonic() - t0 >= 0.15
        # drain acknowledges; the sender may proceed again
        assert buf.wait_any(timeout=1.0) == 0
        rows = buf.dispatch_recv(0)
        assert len(rows) == 1
        buf.dispatch_send(0, 0, _payload(0, 0, np.random.default_rng(SEED)),
                          timeout=1.0)
        assert lockdep.violations() == []
    lockdep.reset()


def test_wake_on_stop_under_lockdep():
    """wait_any parked with no traffic must exit promptly on stop+wake —
    the executor's shutdown path — with the sanitizer installed."""
    with lockdep.lockdep_active(raise_on_violation=True):
        buf = MoEDeviceBuffer(D=2, T=2)
        stop = threading.Event()
        out = {}

        def rx():
            out["r"] = buf.wait_any(timeout=30.0, stop=stop)

        t = threading.Thread(target=rx)
        t.start()
        time.sleep(0.1)
        stop.set()
        buf.wake()
        t.join(timeout=5)
        assert not t.is_alive()
        assert out["r"] is None
        assert lockdep.violations() == []
    lockdep.reset()
