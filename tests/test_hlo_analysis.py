"""HLO static analyzer: loop-trip multipliers must be exact on known graphs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze

MM_FLOPS = 2 * 256 * 512 * 512


def _scan_fn(n):
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=n)
        return h
    return f


X = jax.ShapeDtypeStruct((256, 512), jnp.float32)
W = jax.ShapeDtypeStruct((512, 512), jnp.float32)


def test_scan_flops_scale_with_trip_count():
    for n in (1, 2, 8, 17):
        txt = jax.jit(_scan_fn(n)).lower(X, W).compile().as_text()
        c = analyze(txt)
        np.testing.assert_allclose(c.dot_flops, n * MM_FLOPS, rtol=1e-6)


def test_nested_scan_flops():
    def g(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), ()
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, ()
        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    txt = jax.jit(g).lower(X, W).compile().as_text()
    c = analyze(txt)
    np.testing.assert_allclose(c.dot_flops, 12 * MM_FLOPS, rtol=1e-6)


def test_memory_bytes_grow_with_trips():
    c1 = analyze(jax.jit(_scan_fn(2)).lower(X, W).compile().as_text())
    c2 = analyze(jax.jit(_scan_fn(8)).lower(X, W).compile().as_text())
    assert c2.memory_bytes > c1.memory_bytes * 2


def test_grad_flops_about_triple():
    def loss(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(h ** 2)

    fwd = analyze(jax.jit(loss).lower(X, W).compile().as_text()).dot_flops
    bwd = analyze(jax.jit(jax.grad(loss, argnums=1)).lower(X, W).compile()
                  .as_text()).dot_flops
    assert 2.0 <= bwd / fwd <= 4.5  # fwd+2 bwd matmuls (+ remat variance)


def test_breakdown_lists_top_dots():
    txt = jax.jit(_scan_fn(4)).lower(X, W).compile().as_text()
    c = analyze(txt, breakdown=True)
    assert c.top_dots and c.top_dots[0][0] == 4 * MM_FLOPS
