"""HLO static analyzer: loop-trip multipliers must be exact on known graphs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze

MM_FLOPS = 2 * 256 * 512 * 512


def _scan_fn(n):
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=n)
        return h
    return f


X = jax.ShapeDtypeStruct((256, 512), jnp.float32)
W = jax.ShapeDtypeStruct((512, 512), jnp.float32)


def test_scan_flops_scale_with_trip_count():
    for n in (1, 2, 8, 17):
        txt = jax.jit(_scan_fn(n)).lower(X, W).compile().as_text()
        c = analyze(txt)
        np.testing.assert_allclose(c.dot_flops, n * MM_FLOPS, rtol=1e-6)


def test_nested_scan_flops():
    def g(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), ()
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, ()
        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    txt = jax.jit(g).lower(X, W).compile().as_text()
    c = analyze(txt)
    np.testing.assert_allclose(c.dot_flops, 12 * MM_FLOPS, rtol=1e-6)


def test_memory_bytes_grow_with_trips():
    c1 = analyze(jax.jit(_scan_fn(2)).lower(X, W).compile().as_text())
    c2 = analyze(jax.jit(_scan_fn(8)).lower(X, W).compile().as_text())
    assert c2.memory_bytes > c1.memory_bytes * 2


def test_grad_flops_about_triple():
    def loss(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(h ** 2)

    fwd = analyze(jax.jit(loss).lower(X, W).compile().as_text()).dot_flops
    bwd = analyze(jax.jit(jax.grad(loss, argnums=1)).lower(X, W).compile()
                  .as_text()).dot_flops
    assert 2.0 <= bwd / fwd <= 4.5  # fwd+2 bwd matmuls (+ remat variance)


def test_breakdown_lists_top_dots():
    txt = jax.jit(_scan_fn(4)).lower(X, W).compile().as_text()
    c = analyze(txt, breakdown=True)
    assert c.top_dots and c.top_dots[0][0] == 4 * MM_FLOPS


# ---------------------------------------------------------------------------
# dtype table (ISSUE 7): f8 variants priced, unknown dtypes loud
# ---------------------------------------------------------------------------


def _toy_hlo(dtype):
    return "\n".join([
        f"ENTRY %main (p0: {dtype}[16,8]) -> {dtype}[16,8] {{",
        f"  %p0 = {dtype}[16,8] parameter(0)",
        f"  ROOT %ag = {dtype}[16,8] all-gather(%p0), dimensions={{0}}",
        "}",
    ])


def test_f8_collectives_priced_at_one_byte():
    from repro.launch.hlo_analysis import DTYPE_BYTES, _shape_bytes
    for dt in ("f8e4m3", "f8e5m2", "f8e4m3fn", "f8e5m2fnuz"):
        assert DTYPE_BYTES[dt] == 1
        assert _shape_bytes(f"{dt}[16,8]") == 128
        assert analyze(_toy_hlo(dt)).collective_bytes == 128.0
    # zero-payload sentinel types must not trip the unknown-dtype error
    assert _shape_bytes("token[]") == 0


def test_unknown_dtype_is_a_loud_error():
    import pytest

    from repro.launch.hlo_analysis import _shape_bytes
    with pytest.raises(ValueError, match="unknown HLO dtype 'q7'"):
        _shape_bytes("q7[16,8]")
    with pytest.raises(ValueError, match="DTYPE_BYTES"):
        analyze(_toy_hlo("q7"))
