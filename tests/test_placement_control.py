"""Unified placement control plane (ISSUE 5): the PlacementController policy
family, trace-level parity of the extracted sim rebalancer with PR 2, and the
executor's LIVE expert re-placement."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import (ExpertLoadModel, Placement,
                                   optimal_deployment)
from repro.core.placement_control import (PlacementController, WindowObservation, diff_tables)
from repro.core.simulator import AsapSim, SimConfig

CFG = get_config("deepseek_v32")
EP = 4
N_EXPERTS = 8


def _zipf(n=N_EXPERTS, alpha=1.2):
    p = np.arange(1, n + 1, dtype=np.float64) ** (-alpha)
    return p / p.sum()


def _ctrl(**kw):
    args = dict(ep=EP, num_experts=N_EXPERTS, layers=2,
                target=Placement("replicated", replicate_hot=2),
                bytes_per_copy=100.0,
                initial_fractions=_zipf())
    args.update(kw)
    return PlacementController(**args)


def _obs(imb, n=EP, fractions=None, now=0.0):
    """A busy window whose max/mean equals `imb` exactly: the other devices
    sit at 1.0 and the hot one solves max·(n − imb) = imb·(n − 1)."""
    busy = np.ones(n)
    busy[0] = imb * (n - 1) / max(n - imb, 1e-9)
    return WindowObservation(now=now, busy=busy, fractions=fractions)


def test_window_imbalance_statistic():
    for imb in (1.0, 1.05, 1.5, 2.0):
        assert PlacementController.imbalance(_obs(imb).busy) == \
            pytest.approx(imb)
    assert PlacementController.imbalance(np.zeros(4)) == 1.0  # idle window


# ---------------------------------------------------------------------------
# one_shot_threshold
# ---------------------------------------------------------------------------


def test_one_shot_triggers_once_and_converges():
    c = _ctrl(threshold=1.2)
    assert c.observe(_obs(1.1)) is None  # below threshold: no plan
    assert not c.converged and c.active
    plan = c.observe(_obs(1.3))
    assert plan is not None and plan.placement == c.target
    assert c.converged and not c.active  # one-shot: done forever
    assert c.observe(_obs(5.0)) is None  # never fires again
    # the plan's moves are exactly the new replica copies, receivers pay
    assert plan.moves and all(m.copies == 2 for m in plan.moves)  # 2 layers
    assert plan.total_bytes == pytest.approx(
        sum(m.nbytes for m in plan.moves))
    cost = plan.device_cost(1.0, EP)
    assert cost.sum() == pytest.approx(
        sum(m.copies for m in plan.moves))


def test_one_shot_plan_matches_table_diff():
    c = _ctrl(threshold=1.0)
    plan = c.observe(_obs(1.5))
    fr = tuple(float(x) for x in _zipf())
    old = Placement().table(fr, EP)
    new = c.target.table(fr, EP)
    assert plan.moves == diff_tables(old, new, copies=2,
                                     bytes_per_copy=100.0)
    # every move is a copy that exists in the new table but not the old
    for m in plan.moves:
        assert m.dst in new[m.expert] and m.dst not in old[m.expert]


# ---------------------------------------------------------------------------
# hysteresis
# ---------------------------------------------------------------------------


def test_hysteresis_no_thrash_under_oscillating_load():
    """Load oscillating INSIDE the trigger/release band must cause exactly
    one migration, not a thrash."""
    c = _ctrl(policy="hysteresis", threshold=1.5, release_threshold=1.05,
              cooldown_windows=2)
    plans = [c.observe(_obs(1.6 if i % 2 == 0 else 1.2)) for i in range(20)]
    emitted = [p for p in plans if p is not None]
    assert len(emitted) == 1  # trigger once; 1.2 > release never reverts
    assert c.placement == c.target
    assert c.active  # hysteresis keeps watching forever


def test_hysteresis_reverts_below_release_and_respects_cooldown():
    c = _ctrl(policy="hysteresis", threshold=1.5, release_threshold=1.05,
              cooldown_windows=3)
    assert c.observe(_obs(1.6)) is not None  # window 1: migrate to target
    # quiet load immediately after: cooldown blocks the revert...
    assert c.observe(_obs(1.0)) is None
    assert c.observe(_obs(1.0)) is None
    # ...until it expires, then the placement returns to the boot layout
    plan = c.observe(_obs(1.0))
    assert plan is not None and plan.placement == c.base == Placement()
    # reverting to the round-robin base drops replicas: zero new copies
    assert plan.moves == [] and plan.total_bytes == 0.0


def test_hysteresis_revert_restores_dispatch_copies_override():
    """Regression: reverting to the round-robin base must RESTORE the
    CostModel's closed-form dispatch fan-out, not keep the replicated
    placement's copies_override for the rest of the run."""
    sim = AsapSim(CFG, SimConfig(
        mode="asap", placement="replicated", replicate_hot=2,
        rebalance_interval=3.0, rebalance_policy="hysteresis",
        rebalance_release=1.02))
    assert sim.cm.copies_override is None  # cold round-robin boot
    sim._switch_placement(sim.controller.target)
    assert sim.cm.copies_override is not None
    sim._switch_placement(Placement())
    assert sim.cm.copies_override is None


def test_hysteresis_release_must_not_exceed_trigger():
    with pytest.raises(ValueError):
        _ctrl(policy="hysteresis", threshold=1.1, release_threshold=1.2)


# ---------------------------------------------------------------------------
# partial
# ---------------------------------------------------------------------------


def test_partial_respects_byte_cap_and_converges():
    target = Placement("greedy_balanced")  # full reshuffle: many moves
    full = _ctrl(target=target, threshold=1.0).observe(_obs(1.5))
    assert len(full.moves) > 2
    cap = 2 * 2 * 100.0  # two expert-copies' bytes per window (layers=2)
    c = _ctrl(policy="partial", target=target, threshold=1.0,
              max_bytes_per_window=cap)
    plans = []
    for i in range(32):
        p = c.observe(_obs(1.5))
        if p is not None:
            plans.append(p)
        if c.converged:
            break
    assert c.converged and not c.active
    assert len(plans) >= 2  # converged over several windows, not one shot
    assert all(p.total_bytes <= cap for p in plans)
    assert all(p.partial for p in plans[:-1]) and not plans[-1].partial
    # the union of the plans' moves covers the full one-shot diff
    assert {(m.expert, m.dst) for p in plans for m in p.moves} == \
        {(m.expert, m.dst) for m in full.moves}
    # every intermediate layout keeps every expert hosted
    fr = tuple(float(x) for x in _zipf())
    for p in plans:
        table = p.placement.table(fr, EP)
        assert all(len(h) >= 1 for h in table)


def test_partial_requires_cap():
    with pytest.raises(ValueError):
        _ctrl(policy="partial")


def test_partial_waits_for_trigger_then_runs_to_completion():
    c = _ctrl(policy="partial", target=Placement("greedy_balanced"),
              threshold=1.3, max_bytes_per_window=200.0)
    assert c.observe(_obs(1.1)) is None  # imbalance never tripped: no start
    assert c.observe(_obs(1.4)) is not None  # tripped: migration starts
    # once started, later balanced windows still continue the migration
    # (the imbalance already justified reaching the target)
    went = [c.observe(_obs(1.0)) for _ in range(32)]
    assert c.converged and any(p is not None for p in went)


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------


def test_drift_tracks_moving_zipf_head():
    """A slowly moving hot-expert identity must re-place the replicas onto
    the new head WITHOUT any busy-time imbalance ever crossing a threshold."""
    c = _ctrl(policy="drift", drift_alpha=0.6, cooldown_windows=0,
              threshold=10.0)  # threshold is irrelevant to drift
    frac0 = _zipf()  # expert 0 hottest
    plan0 = c.observe(_obs(1.0, fractions=frac0))
    assert plan0 is not None  # re-derives the table from observed popularity
    hot_hosts0 = plan0.placement.table(c.fractions, EP)[0]
    assert len(hot_hosts0) >= 2  # replicated target: the head gets replicas
    # topic shift: expert 5 becomes the head; EWMA follows over a few windows
    frac1 = np.roll(frac0, 5)
    emitted = []
    for _ in range(8):
        p = c.observe(_obs(1.0, fractions=frac1))
        if p is not None:
            emitted.append(p)
    assert emitted, "drift must re-place after the popularity moved"
    final = emitted[-1].placement.table(c.fractions, EP)
    assert len(final[5]) >= 2, "the new head must hold the replicas"
    assert np.argmax(c.fractions) == 5  # EWMA converged to the new head
    assert c.active  # drift never retires


def test_drift_quiet_when_popularity_stable():
    c = _ctrl(policy="drift", drift_alpha=0.5, cooldown_windows=0)
    fr = _zipf()
    assert c.observe(_obs(1.0, fractions=fr)) is not None  # initial derive
    for _ in range(5):
        assert c.observe(_obs(1.0, fractions=fr)) is None  # stable: silent


# ---------------------------------------------------------------------------
# misc controller contracts
# ---------------------------------------------------------------------------


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        _ctrl(policy="nonsense")


def test_sync_realigns_after_out_of_band_switch():
    c = _ctrl(threshold=1.0)
    failed = c.target.fail(1)
    c.sync(placement=failed, target=failed, base=c.base.fail(1))
    assert c.placement == failed and c.converged
    assert c.base.dead == (1,)


def test_moe_failure_marks_controller_base_dead():
    """Regression: a hysteresis release after a MoE-device failure must
    re-install a boot layout that EXCLUDES the dead device — _fail_moe has
    to sync the controller's base, not just placement/target."""
    sim = AsapSim(CFG, SimConfig(
        mode="asap", rps=1.0, duration=15.0, ep_skew=1.2,
        placement="replicated", replicate_hot=2,
        rebalance_interval=3.0, rebalance_policy="hysteresis",
        rebalance_release=1.0, failure_at=5.0, failure_moe_device=0))
    sim.start()
    sim.run(horizon=200.0)
    assert sim.controller.base.dead == (0,)
    # any base re-install after the failure routes nothing to device 0
    fr = tuple(float(x) for x in sim.load_model.expert_fractions(0))
    assert all(0 not in h
               for h in sim.controller.base.table(fr, sim.ep))


def test_explicit_placement_roundtrip():
    fr = tuple(float(x) for x in _zipf())
    table = Placement("replicated", replicate_hot=2).table(fr, EP)
    pl = Placement.explicit(table)
    assert pl.table(fr, EP) == table
    assert pl.device_experts(fr, EP) == \
        Placement("replicated", replicate_hot=2).device_experts(fr, EP)
    # dead-device failover applies to explicit tables too
    dead = pl.fail(0)
    t = dead.table(fr, EP)
    assert all(0 not in h and len(h) >= 1 for h in t)
    with pytest.raises(ValueError):
        Placement("explicit")  # explicit requires the table
    with pytest.raises(ValueError):
        Placement(table_override=((0,),))  # and the table requires explicit


def test_device_fractions_matches_load_model():
    fr = tuple(float(x) for x in _zipf())
    for pl in (Placement(), Placement("greedy_balanced"),
               Placement("replicated", replicate_hot=2)):
        lm = ExpertLoadModel(num_experts=N_EXPERTS, top_k=2, ep=EP,
                             mode="measured", measured=fr, placement=pl)
        np.testing.assert_allclose(pl.device_fractions(fr, EP),
                                   lm.device_fractions(0), rtol=1e-12)


# ---------------------------------------------------------------------------
# trace-level parity: the extracted controller is bit-exact with PR 2
# ---------------------------------------------------------------------------

# Golden values recorded from the PR-2 inline `AsapSim._rebalance`
# implementation (commit 007a801) immediately before the extraction, as
# float hex — any drift in decision timing, migration charging order, or a
# single float op shows up here.
PR2_GOLDEN = [
    (dict(mode="asap", rps=2.0, duration=20.0, ep_skew=1.2,
          placement="replicated", replicate_hot=2, rebalance_interval=4.0),
     dict(n_done=30, mean="0x1.a225a6d6419d0p-1", p99="0x1.7b92ad07ce3a7p+1",
          busy_sum="0x1.f601d3d333ce8p+5", busy_max="0x1.036d8cabf9637p+2",
          now="0x1.39701a46a530cp+4", inflection=2329)),
    (dict(mode="asap", rps=1.5, duration=15.0, ep_skew=1.0,
          ep_skew_mode="layer", placement="greedy_balanced",
          rebalance_interval=3.0, rebalance_threshold=1.02),
     dict(n_done=22, mean="0x1.e562ab7ba3dd9p-1", p99="0x1.9cb22d8641ae4p+1",
          busy_sum="0x1.2a086a92bf92ep+6", busy_max="0x1.64cc1f32aaefcp+2",
          now="0x1.1b768d151e85bp+4", inflection=1768)),
]


@pytest.mark.parametrize("kw,golden", PR2_GOLDEN)
def test_rebalancer_trace_bit_exact_with_pr2(kw, golden):
    """Acceptance criterion: AsapSim with `rebalance_interval` set and the
    default one_shot_threshold policy reproduces the PR-2 results BIT-exactly
    through the extracted PlacementController."""
    sim = AsapSim(CFG, SimConfig(**kw))
    sim.start()
    sim.run(horizon=200.0)
    t = np.array([r.ttft for r in sim.done])
    assert len(sim.done) == golden["n_done"]
    assert float(t.mean()).hex() == golden["mean"]
    assert float(np.percentile(t, 99)).hex() == golden["p99"]
    assert float(sim.moe_dev_busy_time.sum()).hex() == golden["busy_sum"]
    assert float(sim.moe_dev_busy_time.max()).hex() == golden["busy_max"]
    assert float(sim.now).hex() == golden["now"]
    assert sim.batcher.inflection == golden["inflection"]
    # and the plan history reads back what happened
    assert len(sim.controller.plans) == 1
    assert sim.controller.converged
    assert sim.load_model.placement == sim.controller.target


def test_sim_runs_policy_family_end_to_end():
    """Every policy drives AsapSim to completion through the shared
    _apply_plan path (semantics are policy-specific; completion and
    plan accounting are not)."""
    base = dict(mode="asap", rps=1.5, duration=15.0, ep_skew=1.2,
                placement="replicated", replicate_hot=2,
                rebalance_interval=3.0, rebalance_threshold=1.01)
    for kw in (dict(rebalance_policy="hysteresis", rebalance_release=0.5,
                    rebalance_threshold=1.01),
               dict(rebalance_policy="partial",
                    rebalance_max_bytes=200e6),
               dict(rebalance_policy="drift")):
        sim = AsapSim(CFG, SimConfig(**{**base, **kw}))
        sim.start()
        sim.run(horizon=200.0)
        assert len(sim.done) == sim.total_requests, kw
        if kw["rebalance_policy"] in ("hysteresis", "partial"):
            assert sim.controller.plans, kw  # skew tripped a migration


def test_partial_byte_cap_holds_under_per_layer_tables():
    """Regression: in zipf mode (one target table PER LAYER) the partial
    policy's final step must not re-diff every layer's table against the
    collapsed explicit layout — each emitted plan stays within the
    per-window byte budget (soft floor: one expert)."""
    from repro.core.cost_model import CostModel
    eb = CostModel(CFG).expert_bytes()
    cap = 6.0 * eb * CFG.num_layers  # room for the priciest single expert
    sim = AsapSim(CFG, SimConfig(
        mode="asap", rps=2.0, duration=20.0, ep_skew=1.2,
        ep_skew_mode="zipf", placement="replicated", replicate_hot=2,
        rebalance_interval=2.0, rebalance_policy="partial",
        rebalance_threshold=1.01, rebalance_max_bytes=cap))
    sim.start()
    sim.run(horizon=200.0)
    plans = sim.controller.plans
    assert plans and sim.controller.converged
    assert all(p.total_bytes <= cap for p in plans)
    assert not plans[-1].partial


def test_partial_policy_in_sim_converges_to_target_over_windows():
    sim = AsapSim(CFG, SimConfig(
        mode="asap", rps=2.0, duration=20.0, ep_skew=1.2,
        placement="replicated", replicate_hot=2, rebalance_interval=2.0,
        rebalance_policy="partial", rebalance_threshold=1.01,
        rebalance_max_bytes=50e6))
    sim.start()
    sim.run(horizon=200.0)
    assert sim.controller.converged
    assert len(sim.controller.plans) >= 2  # spread over several windows
    assert sim.load_model.placement.policy in ("explicit", "replicated")
    # final table equals the target's (table-level convergence)
    lm_target = dataclasses.replace(sim.load_model,
                                    placement=sim.controller.target)
    assert sim.load_model.placement_table(0) == lm_target.placement_table(0)


# ---------------------------------------------------------------------------
# executor LIVE re-placement (ROADMAP item (d3)) — slow: threaded + jit
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_executor_live_swap_parity_mid_run():
    """Acceptance criterion: after a mid-run migration, real dispatch
    assignments match ExpertLoadModel under the updated placement, and no
    request is lost or double-processed across the swap."""
    import jax

    from repro.core.engine import ExecutorEngine
    from repro.core.executor import DisaggregatedExecutor
    from repro.core.scheduler import LengthAwareBatcher
    from repro.core.trace import Request, TraceClock
    from repro.models.lm import init_lm_params

    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=3, num_experts=8, top_k=2)
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    ex = DisaggregatedExecutor(params, cfg, D=2, E=4)  # boots round robin
    target = Placement("replicated", replicate_hot=2)
    eng = ExecutorEngine(
        ex, clock=TraceClock(speed=50.0),
        batcher=LengthAwareBatcher(inflection=48, max_tokens=128,
                                   exclusive_cutoff=1 << 30, max_wait=0.05),
        rebalance_interval=1.0, rebalance_threshold=1.0,
        rebalance_target=target)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, arrival=i * 0.4,
                    length=int(rng.choice([8, 16, 24, 32])))
            for i in range(10)]
    handles = eng.submit_all(reqs)
    results = eng.drain(timeout=300)
    st = eng.stats()
    # a migration happened LIVE, while requests were in flight
    assert st.migrations >= 1
    assert st.migrated_bytes > 0
    assert st.placement_policy == "replicated"
    assert ex.migrations[0]["moved_copies"] > 0
    # no lost or double-processed regions: every request completed exactly
    # once, with a real sampled first token
    assert sorted(r.rid for r in results) == list(range(10))
    assert all(h.done() for h in handles)
    assert all(r.first_token is not None for r in results)
    # post-migration executor assignments == ExpertLoadModel under the new
    # placement (the sim/executor shared-routing-layer contract survives
    # the live swap)
    lm = ExpertLoadModel(num_experts=cfg.num_experts, top_k=cfg.top_k, ep=4,
                         mode="measured", measured=ex.expert_fractions,
                         placement=target)
    assert ex.table == lm.placement_table(0)
    assert ex.dev_experts == target.device_experts(ex.expert_fractions, 4)
    for e, hosts in enumerate(ex.table):
        for d in hosts:
            assert e in ex.dev_experts[d]
    eng.close()


# ---------------------------------------------------------------------------
# placement-aware optimal_deployment (ROADMAP item (e))
# ---------------------------------------------------------------------------


def test_optimal_deployment_uniform_matches_legacy():
    legacy = optimal_deployment(CFG)
    aware = optimal_deployment(CFG, placement=Placement())
    # uniform popularity + round robin == the legacy uniform closed form
    assert aware == legacy


def test_optimal_deployment_sizes_moe_pool_off_max_loaded_device():
    skew = tuple(float(x) for x in _zipf(CFG.num_experts, alpha=1.2))
    uni = optimal_deployment(CFG)
    hot = optimal_deployment(CFG, expert_fractions=skew)
    # a skewed popularity concentrates load: the straggler-aware split
    # gives the MoE pool MORE chips (or at minimum never fewer)
    assert hot.E >= uni.E
    # replicating the hot experts flattens the straggler back down
    rep = optimal_deployment(CFG, expert_fractions=skew,
                             placement=Placement("replicated",
                                                 replicate_hot=8))
    assert rep.E <= hot.E


def test_optimal_deployment_handles_explicit_placement():
    """Regression: an explicit table pins absolute device ids; sweeping a
    smaller candidate pool must fall back to the popularity-only view, not
    crash with an IndexError."""
    fr = tuple(float(x) for x in _zipf(CFG.num_experts, alpha=1.2))
    table = Placement("replicated", replicate_hot=2).table(fr, 16)
    dep = optimal_deployment(CFG, placement=Placement.explicit(table),
                             expert_fractions=fr)
    assert dep.E >= optimal_deployment(CFG).E
    # and the table() contract itself rejects an undersized pool loudly
    with pytest.raises(ValueError):
        Placement.explicit(table).table(fr, 4)
