"""Attention: chunked flash-style path vs dense oracle; prefill/decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention_decode, attention_forward, attention_prefill, chunked_causal_attention, dense_causal_attention, init_attention_params, init_kv_cache)
from repro.models.common import ModelConfig

CFG = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, dtype=jnp.float32, attn_chunk=16)


def _qkv(key, B, S, cfg):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, cfg.num_heads, cfg.head_dim))
    k = jax.random.normal(ks[1], (B, S, cfg.num_kv_heads, cfg.head_dim))
    v = jax.random.normal(ks[2], (B, S, cfg.num_kv_heads, cfg.head_dim))
    return q, k, v


@pytest.mark.parametrize("S,chunk,window", [(64, 16, None), (64, 16, 24),
                                            (48, 16, None), (33, 16, None),
                                            (128, 32, 40)])
def test_chunked_matches_dense(S, chunk, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, CFG)
    out_c = chunked_causal_attention(q, k, v, CFG, window, chunk)
    out_d = dense_causal_attention(q, k, v, CFG, window)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("softcap", [None, 10.0])
def test_softcap_paths_agree(softcap):
    cfg = CFG.replace(logit_softcap=softcap)
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 64, cfg)
    out_c = chunked_causal_attention(q, k, v, cfg, None, 16)
    out_d = dense_causal_attention(q, k, v, cfg, None)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_prefill_then_decode_matches_forward(window):
    """Forward over S+1 tokens == prefill(S) + decode(1 token)."""
    cfg = CFG
    key = jax.random.PRNGKey(2)
    p = init_attention_params(key, cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, cfg.d_model))
    full = attention_forward(p, x, cfg, window=window, use_dense=True)
    _, cache = attention_prefill(p, x[:, :S], cfg, window=window,
                                 max_len=S + 1, use_dense=True)
    dec, cache2 = attention_decode(p, x[:, S:S + 1], cache, cfg, window=window)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, S]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache2.length) == S + 1


def test_decode_ring_buffer_wraps():
    cfg = CFG
    p = init_attention_params(jax.random.PRNGKey(4), cfg)
    B, W = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 3 * W, cfg.d_model))
    full = attention_forward(p, x, cfg, window=W, use_dense=True)
    cache = init_kv_cache(cfg, B, max_len=3 * W, window=W)
    outs = []
    for t in range(3 * W):
        o, cache = attention_decode(p, x[:, t:t + 1], cache, cfg, window=W)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec[:, -W:]),
                               np.asarray(full[:, -W:]), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,chunk,window", [(64, 16, None), (64, 16, 24),
                                            (48, 16, None)])
def test_grouped_gqa_matches_expanded(S, chunk, window):
    """cfg.gqa_grouped path == standard head-expanded path."""
    cfg = CFG.replace(gqa_grouped=True)
    q, k, v = _qkv(jax.random.PRNGKey(7), 2, S, CFG)
    out_g = chunked_causal_attention(q, k, v, cfg, window, chunk)
    out_d = dense_causal_attention(q, k, v, CFG, window)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d),
                               rtol=2e-5, atol=2e-5)


def test_perf_knobs_do_not_change_lm_outputs():
    """All §Perf knobs are semantics-preserving (no pshard rules set)."""
    from repro.configs import get_config
    from repro.models.lm import init_lm_params, lm_forward
    base = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_layers=2, num_experts=4, top_k=2, attn_chunk=16)
    params = init_lm_params(jax.random.PRNGKey(0), base)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                base.vocab_size)
    ref, _ = lm_forward(params, base, tokens)
    for kw in ({"gqa_grouped": True}, {"inner_remat": True},
               {"attn_dp_constraint": True}, {"moe_shard_constraints": True}):
        out, _ = lm_forward(params, base.replace(**kw), tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4, err_msg=str(kw))


def test_gqa_expansion_grouping():
    """Each query-head group attends through its own kv head."""
    cfg = CFG
    q, k, v = _qkv(jax.random.PRNGKey(6), 1, 16, cfg)
    out = dense_causal_attention(q, k, v, cfg, None)
    # perturb kv head 1; only query heads 2,3 (group 1) may change
    k2 = k.at[:, :, 1].add(1.0)
    out2 = dense_causal_attention(q, k2, v, cfg, None)
    diff = np.abs(np.asarray(out - out2)).sum(axis=(0, 1, 3))
    assert diff[0] == 0 and diff[1] == 0 and diff[2] > 0 and diff[3] > 0
