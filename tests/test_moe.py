"""MoE layer: dense oracle vs capacity path; dispatch/combine; groups."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.moe import (expert_capacity, init_moe_params, moe_combine, moe_dispatch, moe_forward_capacity, moe_forward_dense, router_topk)

CFG = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
                  vocab_size=64, num_experts=8, top_k=2, moe_d_ff=48,
                  dtype=jnp.float32)


def _setup(cfg=CFG, T=64, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, cfg.d_model))
    return p, x


def test_capacity_matches_dense_when_no_drops():
    cfg = CFG.replace(capacity_factor=8.0)  # ample capacity -> dropless
    p, x = _setup(cfg)
    y_d, aux_d = moe_forward_dense(p, x, cfg)
    y_c, aux_c = moe_forward_capacity(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_d), rtol=2e-4,
                               atol=2e-4)
    assert float(aux_c.dropped_fraction) == 0.0


def test_dispatch_groups_equivalent():
    cfg = CFG.replace(capacity_factor=8.0)
    p, x = _setup(cfg)
    y1, _ = moe_forward_capacity(p, x, cfg.replace(dispatch_groups=1))
    y4, _ = moe_forward_capacity(p, x, cfg.replace(dispatch_groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=2e-4,
                               atol=2e-4)


def test_shared_expert_added():
    cfg = CFG.replace(num_shared_experts=1, capacity_factor=8.0)
    p, x = _setup(cfg)
    y, _ = moe_forward_capacity(p, x, cfg)
    y_no_shared, _ = moe_forward_capacity(
        {k: v for k, v in p.items() if k != "shared"}, x, cfg)
    assert np.abs(np.asarray(y - y_no_shared)).max() > 1e-4


def test_dispatch_combine_roundtrip():
    cfg = CFG
    p, x = _setup(cfg)
    w, idx, _ = router_topk(p["router"], x, cfg)
    xb, info = moe_dispatch(x, idx, cfg, capacity=64)
    # identity experts: combine(yb=xb) == sum_k w_k * x = x (w renormed)
    y = moe_combine(xb, info, w, x.shape[0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2e-4,
                               atol=2e-4)


def test_capacity_drops_counted():
    cfg = CFG.replace(capacity_factor=8.0)
    p, x = _setup(cfg, T=64)
    w, idx, _ = router_topk(p["router"], x, cfg)
    xb, info = moe_dispatch(x, idx, cfg, capacity=2)  # tiny capacity
    dropped = 1.0 - float(jnp.sum(info["valid"])) / idx.size
    assert dropped > 0
    counts = np.asarray(info["group_sizes"])
    assert counts.sum() == idx.size


def test_router_renorm_weights_sum_to_one():
    p, x = _setup()
    w, idx, probs = router_topk(p["router"], x, CFG)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < CFG.num_experts


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss ~= num_experts * E * (1/E) * (1/E) * E = 1."""
    from repro.models.moe import load_balance_loss
    T, E = 512, 8
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], 1)
    lb, _ = load_balance_loss(probs, idx, E)
    np.testing.assert_allclose(float(lb), 2.0, rtol=1e-2)  # K=2 assignments


def test_expert_capacity_alignment():
    cfg = CFG
    c = expert_capacity(1000, cfg)
    assert c % 8 == 0 and c >= 1000 * cfg.top_k / cfg.num_experts
