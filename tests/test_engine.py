"""ServingEngine (ISSUE 4): one request-lifecycle API over the simulator and
the real executor — timed arrivals, streaming out-of-order completions,
measured router statistics, and the sim/executor parity contract."""
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import ExpertLoadModel, resample_fractions
from repro.core.engine import EngineStats, RouterStatsCollector, SimEngine
from repro.core.simulator import SimConfig, run_sim
from repro.core.trace import Request, TraceClock, generate_requests

CFG = get_config("deepseek_v32")


def _check_result_contract(results, requests):
    """One RequestResult per request, monotone non-negative decomposition."""
    assert sorted(r.rid for r in results) == sorted(r.rid for r in requests)
    by_rid = {r.rid: r for r in requests}
    for res in results:
        req = by_rid[res.rid]
        assert res.arrival == req.arrival and res.length == req.length
        assert res.first_token_time >= res.arrival  # monotone timeline
        assert res.ttft >= 0.0
        for k, v in res.decomposition.items():
            assert v >= -1e-12, (res.rid, k, v)
        assert sum(res.decomposition.values()) <= res.ttft * (1 + 1e-6) + 1e-9


# ---------------------------------------------------------------- TraceClock


def test_trace_clock_speed_and_replay():
    c = TraceClock(speed=200.0).start()
    t0 = time.monotonic()
    now = c.sleep_until(1.0)
    wall = time.monotonic() - t0
    assert now >= 1.0
    assert wall < 0.5  # 1 trace-second at 200x is 5 ms wall
    c.start()  # replayable: re-anchor to t=0
    assert c.now() < 0.5


def test_trace_clock_event_wakes_sleep():
    c = TraceClock(speed=1.0).start()
    ev = threading.Event()
    ev.set()
    t0 = time.monotonic()
    c.sleep_until(30.0, event=ev)  # would be 30 s without the event
    assert time.monotonic() - t0 < 1.0


# ------------------------------------------------------- RouterStatsCollector


def test_router_stats_fractions_sum_and_ranking():
    """Acceptance criterion: fractions from a skewed run sum to 1 and rank
    hot experts exactly as the router's measured assignments do."""
    import jax
    from repro.models.moe import router_topk
    cfg = get_config("qwen3_moe_235b_a22b").smoke().replace(
        num_experts=8, top_k=2)
    # a deliberately skewed router: biased logits make a few experts hot
    rng = np.random.RandomState(0)
    router = rng.randn(cfg.d_model, cfg.num_experts).astype(np.float32)
    router[:, 0] += 0.5  # hot expert
    x = rng.randn(512, cfg.d_model).astype(np.float32)
    _, idx, _ = router_topk(jax.numpy.asarray(router),
                            jax.numpy.asarray(x), cfg)
    idx = np.asarray(idx)

    col = RouterStatsCollector(cfg.num_experts)
    for layer in range(3):  # the executor records once per batch-layer
        col.record(layer, idx)
    fr = col.fractions()
    assert fr.sum() == pytest.approx(1.0)
    assert (fr >= 0).all()
    assert col.total == pytest.approx(3 * idx.size)
    # ranking must match the measured assignment histogram exactly
    counts = np.bincount(idx.reshape(-1), minlength=cfg.num_experts)
    assert list(col.hot_experts()) == \
        list(np.argsort(-counts.astype(np.float64), kind="stable"))
    np.testing.assert_allclose(fr, counts / counts.sum())
    # per-layer view: identical rows were recorded on every layer
    np.testing.assert_allclose(col.fractions(layer=1), fr)


def test_router_stats_roundtrip_and_resample(tmp_path):
    col = RouterStatsCollector(4)
    col.record(0, counts=np.array([40.0, 30.0, 20.0, 10.0]))
    p = tmp_path / "stats.json"
    col.save(str(p))
    back = RouterStatsCollector.load(str(p))
    np.testing.assert_allclose(back.fractions(), col.fractions())
    # resampling preserves normalization and descending order
    r = np.asarray(col.resampled(16))
    assert r.sum() == pytest.approx(1.0)
    assert (np.diff(r) <= 1e-12).all()
    # matching expert count: fractions verbatim, identities preserved
    assert col.resampled(4) == col.fractions_tuple()
    # exact-length resample is the sorted vector itself
    np.testing.assert_allclose(resample_fractions((0.1, 0.4, 0.5), 3),
                               [0.5, 0.4, 0.1])


def test_expert_load_model_measured_mode():
    # exact length: fractions used verbatim (identities preserved)
    lm = ExpertLoadModel(num_experts=4, top_k=2, ep=2, mode="measured",
                         measured=(0.4, 0.3, 0.2, 0.1))
    np.testing.assert_allclose(lm.expert_fractions(0), [0.4, 0.3, 0.2, 0.1])
    # layer-correlated: same fractions on every layer
    np.testing.assert_allclose(lm.expert_fractions(3), lm.expert_fractions(0))
    assert lm.hot_fraction() > 1.0 / lm.ep  # skew visible at the device level
    # length mismatch: resampled onto the model's expert count
    lm2 = ExpertLoadModel(num_experts=16, top_k=2, ep=4, mode="measured",
                          measured=(0.7, 0.2, 0.1))
    fr = lm2.expert_fractions(0)
    assert len(fr) == 16 and fr.sum() == pytest.approx(1.0)
    with pytest.raises(ValueError, match="measured"):
        ExpertLoadModel(num_experts=4, top_k=2, ep=2, mode="measured")


def test_sim_config_measured_fractions_resolution():
    sim = SimConfig(mode="asap", ep_skew=1.2,
                    measured_fractions=(0.5, 0.3, 0.2))
    assert sim.resolved_skew() == ("measured", 0.0)
    res = run_sim(CFG, SimConfig(mode="asap", rps=1.0, duration=10.0,
                                 measured_fractions=(0.5, 0.3, 0.2)))
    assert res.completed_fraction() == 1.0


# ------------------------------------------------------------------ SimEngine


def test_sim_engine_streams_and_matches_batch_path():
    """Engine lifecycle over the simulator: submissions with timed arrivals
    produce exactly the batch path's TTFTs, streamed in completion order."""
    sim = SimConfig(mode="asap", rps=2.0, duration=15.0)
    reqs = generate_requests(sim.rps, sim.duration, sim.trace)
    eng = SimEngine(CFG, sim)
    handles = eng.submit_all(reqs)
    first = eng.poll()  # advances virtual time until something completes
    assert first, "poll() must stream the first completion"
    rest = eng.drain()
    results = first + rest
    _check_result_contract(results, reqs)
    # completion order is monotone in virtual completion time
    times = [r.first_token_time for r in results]
    assert times == sorted(times)
    # bit-exact parity with the offline batch driver on the same trace
    batch = run_sim(CFG, SimConfig(mode="asap", rps=2.0, duration=15.0))
    assert {r.rid: r.ttft for r in results} == \
        {r.rid: r.ttft for r in batch.requests}
    # handles were fulfilled out of band
    assert all(h.done() for h in handles)
    assert handles[0].result().rid == reqs[0].rid
    st = eng.stats()
    assert isinstance(st, EngineStats)
    assert st.completed == len(reqs)
    assert st.expert_fractions.sum() == pytest.approx(1.0)
    assert st.moe_device_util is not None and st.moe_device_util.mean() > 0


def test_sim_engine_sync_backend_decomposition():
    sim = SimConfig(mode="default", rps=1.0, duration=10.0)
    reqs = generate_requests(sim.rps, sim.duration, sim.trace)
    eng = SimEngine(CFG, sim)
    eng.submit_all(reqs)
    results = eng.drain()
    _check_result_contract(results, reqs)
    # the sync engine's decomposition partitions the whole TTFT
    for r in results:
        assert sum(r.decomposition.values()) == pytest.approx(r.ttft)


def test_sim_engine_handle_result_fast_forwards():
    sim = SimConfig(mode="asap", rps=1.0, duration=10.0)
    reqs = generate_requests(sim.rps, sim.duration, sim.trace)
    eng = SimEngine(CFG, sim)
    handles = eng.submit_all(reqs)
    last = handles[-1].result()  # drives the event heap to completion
    assert last.rid == reqs[-1].rid and last.ttft >= 0
    # everything that completed on the way is still delivered by poll()
    assert len(eng.poll()) + 1 >= len([h for h in handles if h.done()]) - 1


def test_sim_engine_late_submission_never_rewinds_time():
    """A request submitted after the sim advanced past its arrival is
    admitted at the current virtual time, not in the past."""
    eng = SimEngine(CFG, SimConfig(mode="asap", rps=1.0, duration=5.0))
    eng.submit(Request(rid=0, arrival=0.0, length=1024))
    eng.drain()
    t = eng._sim.now
    eng.submit(Request(rid=1, arrival=0.0, length=1024))  # arrival in past
    res = eng.drain()
    assert len(res) == 1
    assert res[0].first_token_time >= t


def test_sim_engine_router_stats_follow_load_model():
    """Expectation-recorded fractions rank experts exactly as the skewed
    load model does."""
    eng = SimEngine(CFG, SimConfig(mode="asap", rps=1.0, duration=10.0,
                                   ep_skew=1.2, ep_skew_mode="layer"))
    eng.submit_all(generate_requests(1.0, 10.0))
    eng.drain()
    fr = eng.stats().expert_fractions
    assert fr.sum() == pytest.approx(1.0)
    expect = eng._sim.load_model.expert_fractions(0)
    assert list(np.argsort(-fr, kind="stable")) == \
        list(np.argsort(-expect, kind="stable"))
