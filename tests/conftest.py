# NOTE: deliberately does NOT set xla_force_host_platform_device_count —
# smoke tests and benches must see the real (single) host device; only
# launch/dryrun.py (its own process) requests 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
