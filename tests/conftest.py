# NOTE: deliberately does NOT set xla_force_host_platform_device_count —
# smoke tests and benches must see the real (single) host device; only
# launch/dryrun.py (its own process) requests 512 placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

# --- runtime lockdep sanitizer (ISSUE 6) -----------------------------------
# `ASAP_LOCKDEP=1 pytest ...` runs the whole suite with repo-created locks
# instrumented: lock-order inversions and held-lock condition waits raise at
# the offending call, and anything recorded in a worker thread (surfaced via
# the executor's panic path) is re-checked after each test.
if os.environ.get("ASAP_LOCKDEP") == "1":
    import pytest

    from repro.analysis import lockdep

    @pytest.fixture(autouse=True)
    def _asap_lockdep():
        lockdep.reset()
        lockdep.install()
        try:
            yield
            vs = lockdep.violations()
            assert not vs, "lockdep violations:\n" + "\n".join(
                f"[{v.kind}] ({v.thread}) {v.message}" for v in vs)
        finally:
            lockdep.uninstall()
