"""Trace-safe jit usage — asaplint pass 2 must report NOTHING unsuppressed
here.  Never imported; only parsed."""
import threading
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnums=(1,))
def branch_on_static(x, n: int):
    if n > 0:  # static argument: resolved at trace time, no retrace churn
        return x
    return -x


@jax.jit
def branch_on_none(x, bias):
    if bias is None:  # pytree-structural test, fine under trace
        return x
    return x + bias


@jax.jit
def pure_jnp(x):
    return jnp.sum(x) * jnp.arange(4)


@jax.jit
def suppressed(x):
    k = float(x.shape[0])  # retrace-ok: shape is static under trace
    return x * k


class Holder:
    def __init__(self):
        self._lk = threading.Lock()
        self._step = jax.jit(lambda x: x)
        self._n = 0  # guarded_by: _lk

    def run(self, x):
        y = self._step(x)  # compile OUTSIDE the lock
        with self._lk:
            self._n += 1
        return y
