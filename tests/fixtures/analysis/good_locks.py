"""Discipline done right — asaplint pass 1 must report NOTHING unsuppressed
here (tests/test_analysis.py asserts the clean bill).  Never imported."""
import threading


class Account:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._balance = 0  # guarded_by: _lock
        self._audit = []  # guarded_by: protocol

    def deposit(self, x):
        with self._lock:
            self._balance += x

    def balance(self):
        # holding the cv == holding its underlying _lock (alias)
        with self._cv:
            return self._balance

    def wait_nonzero(self):
        with self._cv:
            while self._balance == 0:
                self._cv.wait()
            return self._balance

    def wait_for_nonzero(self):
        with self._cv:
            self._cv.wait_for(lambda: self._balance != 0)

    def try_tick(self):
        if not self._lock.acquire(blocking=False):
            return False
        try:
            self._balance += 1
        finally:
            self._lock.release()
        return True

    def snapshot(self):
        return list(self._audit)  # race-ok: tear-tolerant statistics read


class Ordered:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def f(self):
        with self._a:
            with self._b:
                pass

    def g(self):
        with self._a:
            with self._b:
                pass
