"""Clean sharding idiom — shardcheck must report nothing here."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

ARCHS = ["toy_arch"]
FSDP_ARCHS = {"toy_arch"}

KNOWN_LOGICAL_AXES = frozenset({"batch", "heads"})


def make_toy_mesh(multi_pod: bool = False):
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    shape = (2, 2, 2) if multi_pod else (2, 2)
    return jax.make_mesh(shape, axes)


def good_specs(x):
    a = jax.lax.with_sharding_constraint(x, P("data", "model"))
    b = jax.lax.with_sharding_constraint(x, P(("pod", "data"), None))
    return a, b


def good_rank():
    return jax.device_put(jnp.zeros((4, 8)), P("data", "model"))


def good_logical(x):
    return constrain(x, "batch", None, "heads", None)


def constrain(x, *names):
    return x


@jax.jit
def good_f32(x):
    return x.astype(jnp.float32)


def good_accum(parts):
    acc = jnp.zeros((128,), dtype=jnp.float32)
    for p in parts:
        acc += p
    return acc.astype(jnp.bfloat16)
