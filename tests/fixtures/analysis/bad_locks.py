"""Seeded lock-discipline violations — each rule of asaplint pass 1 must
CATCH something in here (tests/test_analysis.py asserts rule-by-rule).
Never imported; only parsed."""
import threading


class Account:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._balance = 0  # guarded_by: _lock
        self._audit = []  # guarded_by: protocol

    def deposit(self, x):
        self._balance += x  # R1: unguarded write

    def naked_wait(self):
        with self._cv:
            self._cv.wait()  # R3: no predicate loop

    def unheld_wait(self):
        self._cv.wait()  # R3: cv lock not held

    def leak(self):
        self._lock.acquire()  # R4: release not in finally
        self._balance = 0
        self._lock.release()

    def proto(self):
        return self._audit  # R1: protocol access without race-ok

    def proto_empty_reason(self):
        return self._audit  # race-ok:

    def ok(self, x):
        with self._lock:
            self._balance += x


class Snoop:
    def peek(self, acct: Account):
        return acct._balance  # R2: foreign guarded private access


class AB:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def f(self):
        with self._a:
            with self._b:  # R5: cycle with g()
                pass

    def g(self):
        with self._b:
            with self._a:
                pass
