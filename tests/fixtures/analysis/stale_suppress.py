"""A suppression comment with nothing left to suppress — only
`--strict-suppressions` flags it (rule: stale-suppression)."""


def tidy_function(x):
    # race-ok: this hazard was fixed long ago; the comment rotted in place
    return x + 1
