"""Seeded kernelcheck violations — every kc-* rule fires at least once.

NOT importable as real jax code; the static pass only parses it.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bad_kernel(layer_ref, x_ref, w_ref, o_ref):
    # kc-accum-init: += with no pl.when(... == 0) zero-init of o_ref
    # kc-dot-preferred-type: dot without preferred_element_type
    acc = jnp.dot(x_ref[0], w_ref[0])
    o_ref[0] += acc


def bad_gmm(layer_id, w, x):
    E, C, K, N = 4, 192, 256, 256
    # kc-min-clamp: bare min() clamps feeding the grid/block shapes
    bc = min(128, C)
    bn, bk = min(128, N), min(128, K)
    grid = (E, C // bc, N // bn, K // bk)
    return pl.pallas_call(
        _bad_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # kc-index-map-arity: 4 args for grid rank 4 + 1 prefetch
                pl.BlockSpec((1, bc, bk), lambda e, ci, ni, ki: (e, ci, ki)),
                # kc-block-rank: rank-4 block, 3-coordinate index_map
                pl.BlockSpec((1, 1, bk, bn),
                             lambda e, ci, ni, ki, layer: (e, ki, ni)),
            ],
            out_specs=pl.BlockSpec((1, bc, bn),
                                   lambda e, ci, ni, ki, layer: (e, ci, ni)),
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, N), jnp.float32),
    )(layer_id, x, w)


def _dead_prefetch_kernel(slot_ref, y_ref, o_ref):
    del slot_ref
    o_ref[...] = y_ref[...]


def bad_gather(slot, yb):
    N, d = 64, 128
    return pl.pallas_call(
        _dead_prefetch_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            # kc-unused-scalar-prefetch: slot is deleted by the kernel and
            # no index_map consumes its lambda parameter either
            num_scalar_prefetch=1,
            grid=(N,),
            in_specs=[pl.BlockSpec((1, d), lambda i, slot: (i, 0))],
            out_specs=pl.BlockSpec((1, d), lambda i, slot: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, d), yb.dtype),
    )(slot, yb)


def _bf16_dot_kernel(x_ref, w_ref, o_ref):
    # kc-dot-preferred-type (wrong value): accumulating in bf16
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.bfloat16)


def bad_rank(x, w):
    M, N = 128, 128
    return pl.pallas_call(
        _bf16_dot_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((M, N), lambda i: (0, 0)),
                  pl.BlockSpec((M, N), lambda i: (0, 0))],
        # kc-block-rank: rank-2 out block for a rank-3 out_shape
        out_specs=pl.BlockSpec((M, N), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, M, N), jnp.float32),
    )(x, w)


def _suppressed_kernel(x_ref, o_ref):
    # kernel-ok: gauge kernel — first-step garbage is overwritten below
    o_ref[...] += x_ref[...]


def suppressed_accum(x):
    return pl.pallas_call(
        _suppressed_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
    )(x)


def _noreason_kernel(x_ref, o_ref):
    # kernel-ok:
    o_ref[...] += x_ref[...]


def noreason_accum(x):
    return pl.pallas_call(
        _noreason_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
    )(x)
