"""Clean pallas_call idiom — kernelcheck must report nothing here."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import floor_to_divisor


def _kernel(layer_ref, x_ref, w_ref, o_ref):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0] += jnp.dot(x_ref[0], w_ref[0, 0],
                        preferred_element_type=jnp.float32)


def good_gmm(layer_id, w, x):
    E, C, K, N = 4, 192, 256, 256
    bc = floor_to_divisor(C, 128, what="C")
    bn = floor_to_divisor(N, 128, what="N")
    bk = floor_to_divisor(K, 128, what="K")
    grid = (E, C // bc, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, bk),
                             lambda e, ci, ni, ki, layer: (e, ci, ki)),
                pl.BlockSpec((1, 1, bk, bn),
                             lambda e, ci, ni, ki, layer:
                             (layer[0], e, ki, ni)),
            ],
            out_specs=pl.BlockSpec((1, bc, bn),
                                   lambda e, ci, ni, ki, layer: (e, ci, ni)),
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, N), jnp.float32),
    )(layer_id, x, w)


def _copy_kernel(slot_ref, y_ref, o_ref):
    del slot_ref  # consumed by the index_map, not the body
    o_ref[...] = y_ref[...]


def good_gather(slot, yb):
    N, d = 64, 128
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(N,),
            in_specs=[pl.BlockSpec((1, d), lambda i, slot: (slot[i], 0))],
            out_specs=pl.BlockSpec((1, d), lambda i, slot: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, d), yb.dtype),
    )(slot, yb)


def _partial_kernel(x_ref, o_ref, *, scale):
    o_ref[...] = x_ref[...] * scale


def good_partial(x):
    kern = functools.partial(_partial_kernel, scale=2.0)
    return pl.pallas_call(
        kern,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
    )(x)
