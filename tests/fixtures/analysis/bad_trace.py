"""Seeded JAX trace-safety violations — each rule of asaplint pass 2 must
CATCH something in here.  Never imported; only parsed."""
import threading
from functools import partial

import jax
import numpy as np


@jax.jit
def branchy(x):
    if x > 0:  # T1: Python branch on a traced value
        return x
    return -x


@jax.jit
def loopy(x):
    while x.sum() > 0:  # T1: Python while on a traced value
        x = x - 1
    return x


@jax.jit
def mat(x):
    v = float(x)  # T2: host materialization
    s = x.item()  # T2: host materialization
    y = np.sum(x)  # T2: numpy on a traced value
    z = np.arange(4)  # T3: host constant baked into the trace
    return v + s + y + z


@partial(jax.jit, static_argnums=(5,))
def oob(x, y):  # T5: static_argnums out of range
    return x + y


@partial(jax.jit, static_argnums=(1,))
def unhash(x, cfg: dict):  # T5: unhashable static annotation
    return x


class Holder:
    def __init__(self):
        self._lk = threading.Lock()
        self._step = jax.jit(lambda x: x)

    def run(self, x):
        with self._lk:
            f = jax.jit(lambda y: y * 2)  # T4: jit built under a lock
            return self._step(x) + f(x)  # T4: jitted call under a lock
