"""Seeded shardcheck violations — every sc-* rule fires at least once.

NOT importable as real jax code; the static pass only parses it.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# the declared universes this file is checked against
ARCHS = ["toy_arch"]
FSDP_ARCHS = {"toy_arch", "ghost-arch-9000"}  # sc-fsdp-unknown-arch

KNOWN_LOGICAL_AXES = frozenset({"batch", "heads"})


def make_toy_mesh():
    return jax.make_mesh((2, 2), ("data", "model"))


def bad_specs(x):
    # sc-unknown-mesh-axis: "modle" is a typo for "model"
    a = jax.lax.with_sharding_constraint(x, P("data", "modle"))
    # sc-duplicate-mesh-axis
    b = jax.lax.with_sharding_constraint(x, P("data", "data"))
    return a, b


def bad_rank():
    # sc-spec-rank: 3 spec entries for a rank-2 array
    return jax.device_put(jnp.zeros((4, 8)),
                          P("data", "model", None))


def bad_logical(x):
    # sc-unknown-logical-axis: "heds" is a typo for "heads"
    return constrain(x, "heds", None)


def constrain(x, *names):
    return x


@jax.jit
def bad_f64(x):
    # sc-f64-literal: f64 inside jitted code
    return x.astype(jnp.float64)


def bad_accum(parts):
    # sc-bf16-accum: bf16 accumulator fed by +=
    acc = jnp.zeros((128,), dtype=jnp.bfloat16)
    for p in parts:
        acc += p
    return acc


def suppressed_spec(x):
    # shard-ok: deliberate host-only spec exercised by the mesh-compat test
    return jax.lax.with_sharding_constraint(x, P("rows"))
